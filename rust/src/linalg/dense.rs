//! Row-major dense matrix with the handful of BLAS-3 style operations the
//! estimators and baselines need. Deliberately simple; the hot paths of the
//! paper's method are MVMs against *structured* operators, not dense algebra.
//!
//! # Precision contract (see [`crate::util::precision`])
//!
//! [`Mat`] is always f64. [`MatF32`] is a read-only f32 *storage panel* of
//! an f64 matrix, used by the mixed-precision (`Precision::F32F64`) apply
//! paths: the panel halves the bytes the bandwidth-bound GEMM streams, but
//! **every accumulator stays f64** — each stored f32 is widened back to
//! f64 before it enters any product, so [`MatF32::matmul_into_threads`]
//! computes exactly what the f64 kernel would on the rounded matrix
//! `f64::from(a as f32)`. Nothing in this module makes an f32-precision
//! *arithmetic* decision; the only precision loss is the one storage
//! rounding, which keeps the forward error at one ulp(f32) per stored
//! entry (the basis of the operators' n-scaled error bound).

use std::fmt;

/// Row-major dense matrix of f64.
#[derive(Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mat({}x{})", self.rows, self.cols)
    }
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Single-column matrix from a vector — the b=1 bridge into blocked
    /// code paths.
    pub fn from_col(v: &[f64]) -> Self {
        Mat { rows: v.len(), cols: 1, data: v.to_vec() }
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c);
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Column copy (rows are contiguous; columns are strided).
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Copy column `j` into a caller-provided buffer (no allocation).
    pub fn col_into(&self, j: usize, out: &mut [f64]) {
        assert_eq!(out.len(), self.rows);
        for i in 0..self.rows {
            out[i] = self[(i, j)];
        }
    }

    /// Dot product of column `j` with a dense vector (ascending row order,
    /// so it matches a column-copy-then-`dot` bit for bit).
    pub fn col_dot(&self, j: usize, v: &[f64]) -> f64 {
        assert_eq!(v.len(), self.rows);
        let mut s = 0.0;
        for i in 0..self.rows {
            s += self[(i, j)] * v[i];
        }
        s
    }

    /// Dot product of column `j` of `self` with column `j` of `other`
    /// (both strided; ascending row order, matching per-vector `dot`).
    pub fn col_dot_pair(&self, other: &Mat, j: usize) -> f64 {
        assert_eq!(self.rows, other.rows);
        let mut s = 0.0;
        for i in 0..self.rows {
            s += self[(i, j)] * other[(i, j)];
        }
        s
    }

    /// Copy of the column block `[j0, j0 + w)` — how the estimators slice a
    /// probe matrix into MVM blocks.
    pub fn sub_cols(&self, j0: usize, w: usize) -> Mat {
        assert!(j0 + w <= self.cols);
        let mut out = Mat::zeros(self.rows, w);
        for i in 0..self.rows {
            let src = &self.row(i)[j0..j0 + w];
            out.row_mut(i).copy_from_slice(src);
        }
        out
    }

    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self[(i, j)] = v[i];
        }
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// self * x for a vector x.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// y = self * x, no allocation.
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.cols);
        assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            let row = self.row(i);
            let mut s = 0.0;
            for j in 0..self.cols {
                s += row[j] * x[j];
            }
            y[i] = s;
        }
    }

    /// self^T * x.
    pub fn matvec_t(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let row = self.row(i);
            let xi = x[i];
            for j in 0..self.cols {
                y[j] += row[j] * xi;
            }
        }
        y
    }

    /// Blocked i-k-j matmul: cache-friendly without a BLAS dependency.
    pub fn matmul(&self, other: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, other.cols);
        self.matmul_into(other, &mut out);
        out
    }

    /// out = self * other, no allocation (serial).
    pub fn matmul_into(&self, other: &Mat, out: &mut Mat) {
        self.matmul_into_threads(other, out, 1);
    }

    /// out = self * other with the output rows partitioned across up to
    /// `threads` workers (1 = serial). Cache-blocked over k so each panel
    /// of `other` stays resident while a stripe of `self` streams through —
    /// the kernel behind every dense blocked `apply_mat`.
    ///
    /// Accumulation into each output element is in ascending-k order for
    /// any thread count — the same order as `matvec_into`, with no
    /// zero-skipping (a skipped `0.0 * x` term can flip a signed-zero or
    /// drop a NaN) — so a b-column product is bitwise equal to b
    /// single-column `matvec_into` products.
    pub fn matmul_into_threads(&self, other: &Mat, out: &mut Mat, threads: usize) {
        assert_eq!(self.cols, other.rows);
        assert_eq!((out.rows, out.cols), (self.rows, other.cols));
        let (m, k, n) = (self.rows, self.cols, other.cols);
        out.data.fill(0.0);
        if m == 0 || n == 0 {
            return;
        }
        let rows_per = m.div_ceil(threads.max(1)).max(1);
        crate::util::parallel::par_chunks_mut(
            &mut out.data,
            rows_per * n,
            threads,
            |ci, chunk| {
                let row0 = ci * rows_per;
                let nrows = chunk.len() / n;
                const BK: usize = 64;
                for kb in (0..k).step_by(BK) {
                    let kend = (kb + BK).min(k);
                    for r in 0..nrows {
                        let arow = self.row(row0 + r);
                        let orow = &mut chunk[r * n..(r + 1) * n];
                        for kk in kb..kend {
                            let a = arow[kk];
                            let brow = other.row(kk);
                            axpy_row(a, brow, orow);
                        }
                    }
                }
            },
        );
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    /// Maximum absolute entry difference to another matrix.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Symmetrize in place: A <- (A + A^T)/2.
    pub fn symmetrize(&mut self) {
        assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            for j in (i + 1)..self.cols {
                let v = 0.5 * (self[(i, j)] + self[(j, i)]);
                self[(i, j)] = v;
                self[(j, i)] = v;
            }
        }
    }

    pub fn diag(&self) -> Vec<f64> {
        (0..self.rows.min(self.cols)).map(|i| self[(i, i)]).collect()
    }

    /// A += alpha * I
    pub fn add_diag(&mut self, alpha: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self[(i, i)] += alpha;
        }
    }

    pub fn scale(&mut self, alpha: f64) {
        for v in self.data.iter_mut() {
            *v *= alpha;
        }
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Trace of self * other (elementwise dot with other^T) — the exact
    /// baseline's tr(K^{-1} dK) building block.
    pub fn trace_product(&self, other: &Mat) -> f64 {
        assert_eq!(self.cols, other.rows);
        assert_eq!(self.rows, other.cols);
        let mut tr = 0.0;
        for i in 0..self.rows {
            for j in 0..self.cols {
                tr += self[(i, j)] * other[(j, i)];
            }
        }
        tr
    }
}

/// SIMD-friendly row update `o += a * b`: fixed-width accumulator strips
/// via `chunks_exact` so the compiler sees no aliasing and a known trip
/// count. The j-elements are independent (each output element still
/// accumulates in ascending-k order outside), so strip-mining cannot
/// change any result bit.
#[inline]
fn axpy_row(a: f64, b: &[f64], o: &mut [f64]) {
    const STRIP: usize = 8;
    let mut oc = o.chunks_exact_mut(STRIP);
    let mut bc = b.chunks_exact(STRIP);
    for (os, bs) in oc.by_ref().zip(bc.by_ref()) {
        for t in 0..STRIP {
            os[t] += a * bs[t];
        }
    }
    for (ot, bt) in oc.into_remainder().iter_mut().zip(bc.remainder()) {
        *ot += a * bt;
    }
}

/// Row-major f32 storage panel of an f64 matrix — the dense side of the
/// mixed-precision mode (module docs). Read-only by design: panels are
/// built once from the f64 source (`from_mat`) and invalidated whenever
/// the source changes, never mutated in place.
#[derive(Clone, PartialEq)]
pub struct MatF32 {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl fmt::Debug for MatF32 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "MatF32({}x{})", self.rows, self.cols)
    }
}

impl MatF32 {
    /// Round an f64 matrix to its f32 storage panel (one `as f32` rounding
    /// per entry — the only precision loss in the mixed path).
    pub fn from_mat(a: &Mat) -> Self {
        MatF32 {
            rows: a.rows,
            cols: a.cols,
            data: a.data.iter().map(|&v| v as f32).collect(),
        }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// out = self * other with f64 accumulation: the same cache-blocked,
    /// row-partitioned kernel as [`Mat::matmul_into_threads`], streaming
    /// the f32 panel (half the bytes of the f64 kernel's dominant term)
    /// and widening each stored value to f64 before it enters a product.
    /// Bitwise equal to the f64 kernel run on the rounded matrix, for any
    /// thread count.
    pub fn matmul_into_threads(&self, other: &Mat, out: &mut Mat, threads: usize) {
        assert_eq!(self.cols, other.rows);
        assert_eq!((out.rows, out.cols), (self.rows, other.cols));
        let (m, k, n) = (self.rows, self.cols, other.cols);
        out.data.fill(0.0);
        if m == 0 || n == 0 {
            return;
        }
        let rows_per = m.div_ceil(threads.max(1)).max(1);
        crate::util::parallel::par_chunks_mut(
            &mut out.data,
            rows_per * n,
            threads,
            |ci, chunk| {
                let row0 = ci * rows_per;
                let nrows = chunk.len() / n;
                const BK: usize = 64;
                for kb in (0..k).step_by(BK) {
                    let kend = (kb + BK).min(k);
                    for r in 0..nrows {
                        let arow = self.row(row0 + r);
                        let orow = &mut chunk[r * n..(r + 1) * n];
                        for kk in kb..kend {
                            let a = f64::from(arow[kk]);
                            let brow = other.row(kk);
                            axpy_row(a, brow, orow);
                        }
                    }
                }
            },
        );
    }

    /// Allocating wrapper over [`MatF32::matmul_into_threads`].
    pub fn matmul_threads(&self, other: &Mat, threads: usize) -> Mat {
        let mut out = Mat::zeros(self.rows, other.cols);
        self.matmul_into_threads(other, &mut out, threads);
        out
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(&[vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn matvec_and_transpose() {
        let a = Mat::from_rows(&[vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.matvec(&[1.0, 0.0, -1.0]), vec![-2.0, -2.0]);
        assert_eq!(a.matvec_t(&[1.0, 1.0]), vec![5.0, 7.0, 9.0]);
        let t = a.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t[(2, 1)], 6.0);
    }

    #[test]
    fn matmul_matches_matvec() {
        let a = Mat::from_fn(7, 5, |i, j| (i * 5 + j) as f64 * 0.1);
        let b = Mat::from_fn(5, 1, |i, _| i as f64 - 2.0);
        let c = a.matmul(&b);
        let v = a.matvec(&b.col(0));
        for i in 0..7 {
            assert!((c[(i, 0)] - v[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn matmul_into_matches_per_column_matvec_bitwise() {
        // Includes exact-zero entries: no zero-skip shortcuts allowed.
        let a = Mat::from_fn(9, 9, |i, j| {
            if (i + j) % 4 == 0 { 0.0 } else { ((i * 7 + j * 3) % 11) as f64 * 0.37 + 0.1 }
        });
        let b = Mat::from_fn(9, 4, |i, j| (i as f64 - j as f64) * 0.21);
        let mut c = Mat::zeros(9, 4);
        a.matmul_into(&b, &mut c);
        for j in 0..4 {
            let v = a.matvec(&b.col(j));
            for i in 0..9 {
                assert_eq!(c[(i, j)].to_bits(), v[i].to_bits(), "({i},{j})");
            }
        }
        assert_eq!(Mat::from_col(&b.col(1)).col(0), b.col(1));
    }

    #[test]
    fn col_helpers() {
        let a = Mat::from_fn(5, 3, |i, j| (i * 3 + j) as f64);
        let mut buf = vec![0.0; 5];
        a.col_into(1, &mut buf);
        assert_eq!(buf, a.col(1));
        let v = [1.0, -1.0, 2.0, 0.5, 3.0];
        let want: f64 = a.col(2).iter().zip(&v).map(|(x, y)| x * y).sum();
        assert!((a.col_dot(2, &v) - want).abs() < 1e-14);
        let sub = a.sub_cols(1, 2);
        assert_eq!((sub.rows, sub.cols), (5, 2));
        assert_eq!(sub.col(0), a.col(1));
        assert_eq!(sub.col(1), a.col(2));
    }

    #[test]
    fn trace_product_matches_naive() {
        let a = Mat::from_fn(4, 4, |i, j| (i + 2 * j) as f64);
        let b = Mat::from_fn(4, 4, |i, j| (3 * i) as f64 - j as f64);
        let ab = a.matmul(&b);
        let tr: f64 = ab.diag().iter().sum();
        assert!((a.trace_product(&b) - tr).abs() < 1e-10);
    }

    /// The mixed kernel is exactly "round the stored matrix once, then do
    /// f64 arithmetic": it must match the f64 kernel run on the rounded
    /// matrix bit for bit, at any thread count.
    #[test]
    fn f32_panel_matmul_is_f64_matmul_of_rounded_matrix() {
        let a = Mat::from_fn(23, 17, |i, j| {
            if (i + j) % 5 == 0 { 0.0 } else { ((i * 13 + j * 7) % 29) as f64 * 0.113 - 1.1 }
        });
        let b = Mat::from_fn(17, 6, |i, j| (i as f64 * 0.31 - j as f64 * 0.17).sin());
        let panel = MatF32::from_mat(&a);
        let rounded = Mat {
            rows: a.rows,
            cols: a.cols,
            data: a.data.iter().map(|&v| f64::from(v as f32)).collect(),
        };
        for threads in [1usize, 3] {
            let got = panel.matmul_threads(&b, threads);
            let mut want = Mat::zeros(a.rows, b.cols);
            rounded.matmul_into_threads(&b, &mut want, threads);
            for (g, w) in got.data.iter().zip(&want.data) {
                assert_eq!(g.to_bits(), w.to_bits());
            }
        }
    }

    /// Forward error of the mixed GEMM vs full f64: bounded by one
    /// ulp(f32) relative rounding per stored entry, i.e.
    /// `|err| <= eps32 * sum_k |a_ik| |b_kj|` (plus f64 noise).
    #[test]
    fn f32_panel_matmul_error_within_storage_rounding_bound() {
        let a = Mat::from_fn(31, 19, |i, j| ((i * 7 + j * 11) % 23) as f64 * 0.217 - 2.0);
        let b = Mat::from_fn(19, 4, |i, j| (i as f64 + 1.0) * 0.1 - j as f64 * 0.33);
        let exact = a.matmul(&b);
        let got = MatF32::from_mat(&a).matmul_threads(&b, 1);
        let eps32 = f32::EPSILON as f64;
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mag: f64 =
                    (0..a.cols).map(|k| (a[(i, k)] * b[(k, j)]).abs()).sum();
                let err = (got[(i, j)] - exact[(i, j)]).abs();
                assert!(
                    err <= eps32 * mag + 1e-12,
                    "({i},{j}): err {err:e} vs bound {:e}",
                    eps32 * mag
                );
            }
        }
    }

    #[test]
    fn symmetrize_and_diag() {
        let mut a = Mat::from_rows(&[vec![1.0, 2.0], vec![4.0, 5.0]]);
        a.symmetrize();
        assert_eq!(a[(0, 1)], 3.0);
        assert_eq!(a[(1, 0)], 3.0);
        a.add_diag(1.0);
        assert_eq!(a.diag(), vec![2.0, 6.0]);
    }
}
