//! Dense/structured linear-algebra substrates (no external BLAS/LAPACK).
pub mod chol;
pub mod dense;
pub mod eigh;
pub mod fft;
pub mod lu;
pub mod pchol;
pub mod tridiag;
