//! LU factorization with partial pivoting — the surrogate's interpolation
//! saddle system (cubic RBF + polynomial tail, Appendix B.2) is symmetric
//! indefinite, so Cholesky does not apply.

use super::dense::Mat;
use crate::error::{Error, Result};

/// PA = LU factorization (partial pivoting).
pub struct Lu {
    /// Combined L (unit diag, strict lower) and U (upper) factors.
    lu: Mat,
    /// Row permutation.
    piv: Vec<usize>,
    /// Permutation sign.
    sign: f64,
}

impl Lu {
    pub fn new(a: &Mat) -> Result<Self> {
        assert_eq!(a.rows, a.cols);
        let n = a.rows;
        let mut lu = a.clone();
        let mut piv: Vec<usize> = (0..n).collect();
        let mut sign = 1.0;
        for k in 0..n {
            // Pivot search.
            let mut p = k;
            let mut pmax = lu[(k, k)].abs();
            for i in (k + 1)..n {
                let v = lu[(i, k)].abs();
                if v > pmax {
                    pmax = v;
                    p = i;
                }
            }
            if pmax == 0.0 || !pmax.is_finite() {
                return Err(Error::Singular { pivot: k });
            }
            if p != k {
                for j in 0..n {
                    let tmp = lu[(k, j)];
                    lu[(k, j)] = lu[(p, j)];
                    lu[(p, j)] = tmp;
                }
                piv.swap(k, p);
                sign = -sign;
            }
            let pivot = lu[(k, k)];
            for i in (k + 1)..n {
                let m = lu[(i, k)] / pivot;
                lu[(i, k)] = m;
                if m != 0.0 {
                    for j in (k + 1)..n {
                        let v = lu[(k, j)];
                        lu[(i, j)] -= m * v;
                    }
                }
            }
        }
        Ok(Lu { lu, piv, sign })
    }

    pub fn n(&self) -> usize {
        self.lu.rows
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let n = self.n();
        assert_eq!(b.len(), n);
        // Apply permutation.
        let mut x: Vec<f64> = self.piv.iter().map(|&p| b[p]).collect();
        // Forward: L y = Pb (unit diagonal).
        for i in 0..n {
            let ri = i * n;
            let mut s = x[i];
            for k in 0..i {
                s -= self.lu.data[ri + k] * x[k];
            }
            x[i] = s;
        }
        // Backward: U x = y.
        for i in (0..n).rev() {
            let ri = i * n;
            let mut s = x[i];
            for k in (i + 1)..n {
                s -= self.lu.data[ri + k] * x[k];
            }
            x[i] = s / self.lu.data[ri + i];
        }
        x
    }

    /// Determinant (sign * product of U diagonal).
    pub fn det(&self) -> f64 {
        let mut d = self.sign;
        for i in 0..self.n() {
            d *= self.lu[(i, i)];
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solve_general() {
        let a = Mat::from_rows(&[
            vec![0.0, 2.0, 1.0],
            vec![1.0, -1.0, 0.0],
            vec![3.0, 0.0, -2.0],
        ]);
        let x_true = vec![1.0, -2.0, 3.0];
        let b = a.matvec(&x_true);
        let lu = Lu::new(&a).unwrap();
        let x = lu.solve(&b);
        for i in 0..3 {
            assert!((x[i] - x_true[i]).abs() < 1e-10);
        }
    }

    #[test]
    fn det_matches() {
        let a = Mat::from_rows(&[vec![2.0, 1.0], vec![1.0, 3.0]]);
        assert!((Lu::new(&a).unwrap().det() - 5.0).abs() < 1e-12);
        // Permutation-needing matrix.
        let b = Mat::from_rows(&[vec![0.0, 1.0], vec![1.0, 0.0]]);
        assert!((Lu::new(&b).unwrap().det() + 1.0).abs() < 1e-12);
    }

    #[test]
    fn singular_detected() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]);
        assert!(Lu::new(&a).is_err());
    }

    #[test]
    fn indefinite_saddle_system() {
        // [A P; P^T 0] style system — what the surrogate solves.
        let a = Mat::from_rows(&[
            vec![2.0, 0.5, 1.0],
            vec![0.5, 1.0, 1.0],
            vec![1.0, 1.0, 0.0],
        ]);
        let x_true = vec![0.3, -0.7, 1.1];
        let b = a.matvec(&x_true);
        let x = Lu::new(&a).unwrap().solve(&b);
        for i in 0..3 {
            assert!((x[i] - x_true[i]).abs() < 1e-10);
        }
    }
}
