//! Cross-module integration tests: estimators over structured operators,
//! full training loops, Laplace models, and the experiment drivers
//! themselves (Small scale smoke + shape assertions).

use gpsld::coordinator::{cli, Scale};
use gpsld::data;
use gpsld::estimators::exact;
use gpsld::estimators::slq::{slq_logdet, SlqOptions};
use gpsld::gp::laplace::{LaplaceGp, LaplaceOptions};
use gpsld::gp::likelihoods::Likelihood;
use gpsld::gp::regression::{Estimator, GpRegression};
use gpsld::grid::{Grid, GridDim, InterpOrder};
use gpsld::kernels::{IsoKernel, SeparableKernel, Shape};
use gpsld::operators::ski::KronKernelOp;
use gpsld::operators::{DenseKernelOp, KernelOp, SkiOp, SumKernelOp};
use gpsld::opt::lbfgs::LbfgsOptions;
use gpsld::util::rng::Rng;

#[test]
fn slq_on_ski_matches_exact_logdet() {
    let mut rng = Rng::new(1);
    let pts: Vec<Vec<f64>> = (0..300).map(|_| vec![rng.uniform_in(0.0, 4.0)]).collect();
    let grid = Grid::new(vec![GridDim { lo: -0.2, hi: 4.2, m: 500 }]);
    let ski = SkiOp::new(
        &pts,
        grid,
        SeparableKernel::iso(Shape::Rbf, 1, 0.3, 1.0),
        0.2,
        InterpOrder::Cubic,
        false,
    );
    let est = slq_logdet(
        &ski,
        &SlqOptions { steps: 30, probes: 10, seed: 2, ..Default::default() },
    )
    .unwrap();
    let truth = exact::exact_logdet(&ski).unwrap();
    assert!(
        (est.value - truth).abs() < 0.05 * truth.abs().max(1.0) + 4.0 * est.std_err,
        "{} vs {truth}",
        est.value
    );
}

#[test]
fn additive_kernel_slq_where_scaled_eig_cannot_go() {
    // The paper's motivating case: a sum of kernels has fast MVMs but no
    // joint eigendecomposition. SLQ handles it; scaled-eig refuses.
    let mut rng = Rng::new(3);
    let pts: Vec<Vec<f64>> = (0..150).map(|_| vec![rng.gaussian()]).collect();
    let a = DenseKernelOp::new(
        pts.clone(),
        Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
        1.0,
    );
    let b = DenseKernelOp::new(
        pts,
        Box::new(IsoKernel::new(Shape::Matern32, 1, 2.0, 0.6)),
        1.0,
    );
    let sum = SumKernelOp::new(vec![Box::new(a), Box::new(b)], 0.3);
    let est = slq_logdet(
        &sum,
        &SlqOptions { steps: 30, probes: 10, seed: 4, ..Default::default() },
    )
    .unwrap();
    let truth = exact::exact_logdet(&sum).unwrap();
    assert!((est.value - truth).abs() < 0.05 * truth.abs().max(1.0) + 4.0 * est.std_err);
    assert_eq!(est.grad.len(), sum.num_hypers());
}

#[test]
fn diag_corrected_ski_trains_end_to_end() {
    // Diagonal correction + SLQ + L-BFGS: the combination the
    // scaled-eigenvalue approach cannot do at all (paper §3.3).
    let truth_kern = IsoKernel::new(Shape::Matern32, 1, 0.2, 1.0);
    let d = data::gp_1d(400, 0.0, 4.0, false, &truth_kern, 0.1, 5);
    let grid = Grid::covering(&d.x_train, &[300], 0.05);
    let ski = SkiOp::new(
        &d.x_train,
        grid,
        SeparableKernel::iso(Shape::Matern32, 1, 0.5, 0.7),
        0.3,
        InterpOrder::Cubic,
        true,
    );
    let mut gp = GpRegression::new(ski, d.y_train.clone());
    gp.mean = 0.0;
    let (before, _) = gp
        .mll(
            &Estimator::Slq(SlqOptions { steps: 25, probes: 5, seed: 6, ..Default::default() }),
            false,
        )
        .unwrap();
    let stats = gp
        .train(
            &Estimator::Slq(SlqOptions { steps: 25, probes: 5, seed: 6, ..Default::default() }),
            &LbfgsOptions { max_iters: 10, g_tol: 1e-4, ..Default::default() },
        )
        .unwrap();
    assert!(stats.final_mll > before, "{before} -> {}", stats.final_mll);
    // Recovered lengthscale within a broad factor of truth.
    let ell = stats.final_hypers[0].exp();
    assert!(ell > 0.05 && ell < 0.6, "ell {ell}");
}

#[test]
fn lgcp_laplace_recovers_intensity_shape() {
    let cg = data::hickory(20, 0.8, 0.2, 500.0, 7);
    let kern = SeparableKernel::iso(Shape::Rbf, 2, 0.2, 0.8);
    let op = KronKernelOp::new(cg.grid.clone(), kern, 1e-2);
    let mut gp = LaplaceGp::new(op, cg.counts.clone(), Likelihood::Poisson { offset: cg.offset });
    let fit = gp.fit(&LaplaceOptions::default()).unwrap();
    // Latent recovery: correlation with the generating field.
    let f = &fit.f_hat;
    let t = &cg.latent;
    let (mf, mt) = (gpsld::util::stats::mean(f), gpsld::util::stats::mean(t));
    let mut num = 0.0;
    let mut df = 0.0;
    let mut dt = 0.0;
    for i in 0..f.len() {
        num += (f[i] - mf) * (t[i] - mt);
        df += (f[i] - mf).powi(2);
        dt += (t[i] - mt).powi(2);
    }
    let corr = num / (df.sqrt() * dt.sqrt()).max(1e-12);
    assert!(corr > 0.6, "latent corr {corr}");
    assert!(fit.log_marginal.is_finite());
}

#[test]
fn fig6_shape_diag_correction_restores_uncertainty() {
    // fig6: diagonal correction must not shrink uncertainty in the gap
    // below the uncorrected version, and should land nearer FITC.
    let res = cli::run_experiment("fig6", Scale::Small).unwrap();
    let get = |name: &str, col: usize| -> f64 {
        res.rows.iter().find(|r| r[0] == name).unwrap()[col].parse().unwrap()
    };
    let diag_gap = get("ski_diag", 1);
    let nodiag_gap = get("ski_nodiag", 1);
    let fitc_gap = get("fitc", 1);
    assert!(diag_gap >= nodiag_gap, "diag {diag_gap} vs nodiag {nodiag_gap}");
    assert!(
        (diag_gap - fitc_gap).abs() <= (nodiag_gap - fitc_gap).abs() + 1e-9,
        "diag should track FITC at least as closely"
    );
}

#[test]
fn fig5_shape_lanczos_tracks_spectrum_mass() {
    let res = cli::run_experiment("fig5", Scale::Small).unwrap();
    // The lowest bucket holds most of the spectrum — the Ritz-weighted
    // count must agree within ~5%; the Chebyshev log error must be largest
    // in that same bucket (the paper's C.2 argument).
    let first = &res.rows[0];
    let true_count: f64 = first[1].parse().unwrap();
    let ritz_count: f64 = first[2].parse().unwrap();
    assert!((ritz_count - true_count).abs() / true_count < 0.05);
    let errs: Vec<f64> = res.rows.iter().map(|r| r[3].parse().unwrap()).collect();
    let max_err = errs.iter().cloned().fold(0.0, f64::max);
    assert_eq!(errs[0], max_err, "cheb error should peak near lambda_min");
}

#[test]
fn cli_info_and_usage_paths() {
    assert_eq!(cli::main_with_args(&["info".into()]), 0);
    assert_eq!(cli::main_with_args(&["exp".into()]), 2);
}

#[test]
fn hessian_estimator_is_finite_and_symmetric() {
    let mut rng = Rng::new(9);
    let pts: Vec<Vec<f64>> = (0..60).map(|_| vec![rng.uniform_in(0.0, 2.0)]).collect();
    let mut op = DenseKernelOp::new(
        pts,
        Box::new(IsoKernel::new(Shape::Rbf, 1, 0.4, 1.0)),
        0.3,
    );
    let est = gpsld::estimators::hessian::logdet_hessian(
        &mut op,
        &gpsld::estimators::hessian::HessianOptions {
            steps: 30,
            probes: 20,
            seed: 10,
            ..Default::default()
        },
    )
    .unwrap();
    for i in 0..3 {
        for j in 0..3 {
            assert!(est.mean[i][j].is_finite());
            assert_eq!(est.mean[i][j], est.mean[j][i]);
        }
    }
}
