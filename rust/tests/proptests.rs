//! Property-based tests (the offline registry carries no proptest; this is
//! a small seeded-generator harness with many random cases per property).
//! Invariants checked:
//!   * operators are symmetric and PSD-consistent,
//!   * SKI MVMs converge to the exact kernel as the grid refines,
//!   * estimators are unbiased-consistent across seeds,
//!   * the surrogate interpolates exactly,
//!   * Toeplitz/Kron structure matches dense materialization.

use gpsld::grid::{Grid, GridDim, InterpOrder};
use gpsld::kernels::{IsoKernel, Kernel, SeparableKernel, Shape};
use gpsld::linalg::dense::Mat;
use gpsld::operators::ski::KronKernelOp;
use gpsld::operators::toeplitz::ToeplitzOp;
use gpsld::operators::{
    DenseKernelOp, DenseMatOp, FitcOp, KernelOp, KronFactor, KronOp, LinOp, SkiOp, SumKernelOp,
};
use gpsld::util::precision::Precision;
use gpsld::util::rng::Rng;

const SHAPES: [Shape; 4] = [Shape::Rbf, Shape::Matern12, Shape::Matern32, Shape::Matern52];

fn rand_shape(rng: &mut Rng) -> Shape {
    SHAPES[rng.below(4)]
}

/// Property: every kernel operator is symmetric — u^T (K v) == v^T (K u).
#[test]
fn prop_operators_symmetric() {
    let mut rng = Rng::new(100);
    for case in 0..25 {
        let n = 20 + rng.below(60);
        let d = 1 + rng.below(3);
        let pts: Vec<Vec<f64>> =
            (0..n).map(|_| (0..d).map(|_| rng.gaussian()).collect()).collect();
        let shape = rand_shape(&mut rng);
        let op = DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(shape, d, 0.2 + rng.uniform(), 0.5 + rng.uniform())),
            0.1 + 0.5 * rng.uniform(),
        );
        let u: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let v: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let ku = op.apply_vec(&u);
        let kv = op.apply_vec(&v);
        let a: f64 = u.iter().zip(&kv).map(|(x, y)| x * y).sum();
        let b: f64 = v.iter().zip(&ku).map(|(x, y)| x * y).sum();
        assert!((a - b).abs() < 1e-8 * (1.0 + a.abs()), "case {case}: {a} vs {b}");
    }
}

/// Property: quadratic forms are positive (operators are PD with noise).
#[test]
fn prop_operators_positive_definite() {
    let mut rng = Rng::new(200);
    for _ in 0..25 {
        let n = 15 + rng.below(50);
        let pts: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.gaussian()]).collect();
        let shape = rand_shape(&mut rng);
        let op = DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(shape, 1, 0.3 + rng.uniform(), 1.0)),
            0.05 + 0.3 * rng.uniform(),
        );
        let z: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let kz = op.apply_vec(&z);
        let q: f64 = z.iter().zip(&kz).map(|(a, b)| a * b).sum();
        assert!(q > 0.0, "quadratic form {q}");
    }
}

/// Property: Toeplitz FFT MVM == dense Toeplitz MVM for random columns.
#[test]
fn prop_toeplitz_matches_dense() {
    let mut rng = Rng::new(300);
    for _ in 0..30 {
        let m = 2 + rng.below(120);
        // SPD-ish decaying column so values stay tame.
        let col: Vec<f64> =
            (0..m).map(|k| (1.0 + rng.uniform()) * (-0.1 * k as f64).exp()).collect();
        let op = ToeplitzOp::new(col.clone());
        let x: Vec<f64> = (0..m).map(|_| rng.gaussian()).collect();
        let got = op.apply_vec(&x);
        for i in 0..m {
            let want: f64 = (0..m).map(|j| col[i.abs_diff(j)] * x[j]).sum();
            assert!((got[i] - want).abs() < 1e-8 * (1.0 + want.abs()));
        }
    }
}

/// Property: SKI error decreases as the grid refines (for a fixed smooth
/// kernel and fixed probe vector).
#[test]
fn prop_ski_converges_with_grid_refinement() {
    let mut rng = Rng::new(400);
    for _ in 0..8 {
        let n = 60;
        let pts: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform_in(0.0, 2.0)]).collect();
        let ell = 0.3 + 0.4 * rng.uniform();
        let kern = SeparableKernel::iso(Shape::Rbf, 1, ell, 1.0);
        let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        // Exact MVM.
        let mut exact = vec![0.0; n];
        for i in 0..n {
            let mut s = 0.04 * x[i];
            for j in 0..n {
                s += kern.eval(&pts[i], &pts[j]) * x[j];
            }
            exact[i] = s;
        }
        let err_at = |m: usize| -> f64 {
            let grid = Grid::new(vec![GridDim { lo: -0.1, hi: 2.1, m }]);
            let ski = SkiOp::new(&pts, grid, kern.clone(), 0.2, InterpOrder::Cubic, false);
            let got = ski.apply_vec(&x);
            got.iter()
                .zip(&exact)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max)
        };
        let coarse = err_at(24);
        let fine = err_at(192);
        assert!(fine <= coarse + 1e-12, "coarse {coarse} fine {fine}");
    }
}

/// Property: SLQ logdet estimates from disjoint seeds agree within their
/// combined error bars (consistency of the a-posteriori error estimate).
#[test]
fn prop_slq_seed_consistency() {
    use gpsld::estimators::slq::{slq_logdet, SlqOptions};
    let mut rng = Rng::new(500);
    for case in 0..6 {
        let n = 100 + rng.below(100);
        let pts: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
        let op = DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(rand_shape(&mut rng), 1, 0.4, 1.0)),
            0.3,
        );
        let a = slq_logdet(
            &op,
            &SlqOptions { steps: 30, probes: 10, grads: false, seed: 1000 + case, ..Default::default() },
        )
        .unwrap();
        let b = slq_logdet(
            &op,
            &SlqOptions { steps: 30, probes: 10, grads: false, seed: 2000 + case, ..Default::default() },
        )
        .unwrap();
        let tol = 5.0 * (a.std_err + b.std_err) + 0.01 * a.value.abs();
        assert!(
            (a.value - b.value).abs() < tol,
            "case {case}: {} vs {} (tol {tol})",
            a.value,
            b.value
        );
    }
}

/// Property: the RBF surrogate interpolates its design values exactly for
/// random point sets (nonsingularity of the saddle system).
#[test]
fn prop_surrogate_interpolates() {
    use gpsld::estimators::surrogate::RbfSurrogate;
    let mut rng = Rng::new(600);
    for _ in 0..20 {
        let d = 1 + rng.below(4);
        let n = d + 2 + rng.below(20);
        let pts: Vec<Vec<f64>> =
            (0..n).map(|_| (0..d).map(|_| rng.gaussian()).collect()).collect();
        let vals: Vec<f64> = (0..n).map(|_| rng.gaussian() * 10.0).collect();
        // Skip degenerate point sets (duplicates).
        let mut ok = true;
        for i in 0..n {
            for j in 0..i {
                if gpsld::kernels::dist(&pts[i], &pts[j]) < 1e-9 {
                    ok = false;
                }
            }
        }
        if !ok {
            continue;
        }
        if let Ok(s) = RbfSurrogate::fit(pts.clone(), &vals) {
            for (p, v) in pts.iter().zip(&vals) {
                assert!((s.eval(p) - v).abs() < 1e-6 * (1.0 + v.abs()));
            }
        }
    }
}

/// Max tolerance for "blocked == per-column" comparisons (the block-probe
/// contract promises bitwise identity; 1e-12 relative leaves headroom for
/// future implementations that reassociate).
const BLOCK_TOL: f64 = 1e-12;

fn assert_apply_mat_matches(name: &str, op: &dyn LinOp, x: &Mat) {
    let y = op.apply_mat(x);
    assert_eq!((y.rows, y.cols), (x.rows, x.cols), "{name} shape");
    for j in 0..x.cols {
        let col = op.apply_vec(&x.col(j));
        for i in 0..x.rows {
            assert!(
                (y[(i, j)] - col[i]).abs() <= BLOCK_TOL * (1.0 + col[i].abs()),
                "{name} apply_mat ({i},{j}): {} vs {}",
                y[(i, j)],
                col[i]
            );
        }
    }
}

fn assert_grad_mats_match(name: &str, op: &dyn KernelOp, x: &Mat) {
    let all = op.apply_grad_all_mat(x);
    assert_eq!(all.len(), op.num_hypers(), "{name} grad count");
    let mut col = vec![0.0; x.rows];
    for i in 0..op.num_hypers() {
        let gm = op.apply_grad_mat(i, x);
        for j in 0..x.cols {
            op.apply_grad(i, &x.col(j), &mut col);
            for r in 0..x.rows {
                assert!(
                    (gm[(r, j)] - col[r]).abs() <= BLOCK_TOL * (1.0 + col[r].abs()),
                    "{name} apply_grad_mat hyper {i} ({r},{j}): {} vs {}",
                    gm[(r, j)],
                    col[r]
                );
                assert!(
                    (all[i][(r, j)] - col[r]).abs() <= BLOCK_TOL * (1.0 + col[r].abs()),
                    "{name} apply_grad_all_mat hyper {i} ({r},{j}): {} vs {}",
                    all[i][(r, j)],
                    col[r]
                );
            }
        }
    }
}

/// Property (block-probe contract): `apply_mat` / `apply_grad_mat` /
/// `apply_grad_all_mat` match column-by-column `apply` / `apply_grad` for
/// every operator type — dense kernel, plain dense, Toeplitz, Kronecker,
/// SKI (both diagonal-correction modes), grid Kron kernel, FITC and SoR,
/// additive sums, and the shifted/diagonal wrappers.
#[test]
fn prop_blocked_applies_match_columns() {
    let mut rng = Rng::new(900);
    let n = 24;
    let b = 5;
    let pts1: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform_in(0.0, 2.0)]).collect();
    let pts2: Vec<Vec<f64>> =
        (0..n).map(|_| vec![rng.uniform(), rng.uniform()]).collect();
    let x = Mat::from_fn(n, b, |_, _| rng.gaussian());

    // Dense kernel operator.
    let dense = DenseKernelOp::new(
        pts1.clone(),
        Box::new(IsoKernel::new(Shape::Matern32, 1, 0.4, 1.1)),
        0.2,
    );
    assert_apply_mat_matches("dense_kernel", &dense, &x);
    assert_grad_mats_match("dense_kernel", &dense, &x);

    // Plain dense matrix operator.
    let mut a = Mat::from_fn(n, n, |_, _| rng.gaussian());
    a.symmetrize();
    a.add_diag(n as f64);
    assert_apply_mat_matches("dense_mat", &DenseMatOp::new(a.clone()), &x);

    // Toeplitz.
    let col: Vec<f64> = (0..n).map(|k| (1.5 + rng.uniform()) * (-0.1 * k as f64).exp()).collect();
    assert_apply_mat_matches("toeplitz", &ToeplitzOp::new(col.clone()), &x);

    // Kronecker (dense x toeplitz x dense), n = 2*4*3 = 24.
    let mut ka = Mat::from_fn(2, 2, |_, _| rng.gaussian());
    ka.symmetrize();
    ka.add_diag(2.0);
    let mut kc = Mat::from_fn(3, 3, |_, _| rng.gaussian());
    kc.symmetrize();
    kc.add_diag(3.0);
    let kron = KronOp::new(
        vec![
            KronFactor::Dense(ka),
            KronFactor::Toeplitz(ToeplitzOp::new(vec![2.0, 0.8, 0.1, 0.02])),
            KronFactor::Dense(kc),
        ],
        1.3,
    );
    assert_apply_mat_matches("kron", &kron, &x);

    // SKI with and without the diagonal correction.
    for diag_corr in [false, true] {
        let grid = Grid::new(vec![GridDim { lo: -0.1, hi: 2.1, m: 16 }]);
        let ski = SkiOp::new(
            &pts1,
            grid,
            SeparableKernel::iso(Shape::Rbf, 1, 0.3, 1.0),
            0.15,
            InterpOrder::Cubic,
            diag_corr,
        );
        let name = if diag_corr { "ski_diag" } else { "ski" };
        assert_apply_mat_matches(name, &ski, &x);
        assert_grad_mats_match(name, &ski, &x);
    }

    // Grid Kron kernel operator (W = I), n = 6*4 = 24.
    let grid2 = Grid::new(vec![
        GridDim { lo: 0.0, hi: 1.0, m: 6 },
        GridDim { lo: 0.0, hi: 1.0, m: 4 },
    ]);
    let kk = KronKernelOp::new(grid2, SeparableKernel::iso(Shape::Matern52, 2, 0.5, 0.9), 0.1);
    assert_apply_mat_matches("kron_kernel", &kk, &x);
    assert_grad_mats_match("kron_kernel", &kk, &x);

    // FITC and SoR.
    for fitc in [false, true] {
        let ind: Vec<Vec<f64>> = (0..6).map(|i| vec![2.0 * i as f64 / 5.0]).collect();
        let op = FitcOp::new(
            pts1.clone(),
            ind,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
            0.25,
            fitc,
        )
        .unwrap();
        let name = if fitc { "fitc" } else { "sor" };
        assert_apply_mat_matches(name, &op, &x);
        assert_grad_mats_match(name, &op, &x);
    }

    // Additive sum of two dense kernels.
    let p1 = DenseKernelOp::new(
        pts2.clone(),
        Box::new(IsoKernel::new(Shape::Rbf, 2, 0.5, 1.0)),
        1.0,
    );
    let p2 = DenseKernelOp::new(
        pts2.clone(),
        Box::new(IsoKernel::new(Shape::Matern12, 2, 0.8, 0.6)),
        1.0,
    );
    let sum = SumKernelOp::new(vec![Box::new(p1), Box::new(p2)], 0.3);
    assert_apply_mat_matches("sum", &sum, &x);
    assert_grad_mats_match("sum", &sum, &x);

    // Shifted view over a dense operator.
    let base = DenseMatOp::new(a);
    let shifted = gpsld::operators::ShiftedOp { inner: &base, shift: 0.9 };
    assert_apply_mat_matches("shifted", &shifted, &x);

    // Preconditioned split wrapper P^{-1/2} K̃ P^{-1/2} (the SLQ operator):
    // its blocked apply chains three blocked applies and must stay
    // column-independent like every other wrapper.
    {
        use gpsld::solvers::{build_preconditioner, PrecondOptions, PreconditionedOp};
        let pc = build_preconditioner(&dense, PrecondOptions::rank(6)).unwrap();
        let pop = PreconditionedOp::new(&dense, &pc);
        assert_apply_mat_matches("preconditioned_split", &pop, &x);
    }
}

/// Regression (block-probe contract, estimator level): SLQ estimates are
/// bit-identical at b=1 and b=8 under a fixed seed, including on the
/// structured SKI path where block applies go through the shared FFT plan.
#[test]
fn prop_slq_block_invariance() {
    use gpsld::estimators::slq::{slq_logdet, SlqOptions};
    let mut rng = Rng::new(950);
    let n = 80;
    let pts: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
    let grid = Grid::covering(&pts, &[40], 0.1);
    let ski = SkiOp::new(
        &pts,
        grid,
        SeparableKernel::iso(Shape::Rbf, 1, 0.3, 1.0),
        0.2,
        InterpOrder::Cubic,
        false,
    );
    let dense = DenseKernelOp::new(
        pts,
        Box::new(IsoKernel::new(Shape::Rbf, 1, 0.3, 1.0)),
        0.2,
    );
    for (name, op) in [("ski", &ski as &dyn KernelOp), ("dense", &dense)] {
        let e1 = slq_logdet(
            op,
            &SlqOptions { steps: 20, probes: 8, seed: 42, block_size: 1, ..Default::default() },
        )
        .unwrap();
        let e8 = slq_logdet(
            op,
            &SlqOptions { steps: 20, probes: 8, seed: 42, block_size: 8, ..Default::default() },
        )
        .unwrap();
        assert_eq!(
            e1.value.to_bits(),
            e8.value.to_bits(),
            "{name}: {} vs {}",
            e1.value,
            e8.value
        );
        assert_eq!(e1.std_err.to_bits(), e8.std_err.to_bits(), "{name} std_err");
        assert_eq!(e1.grad.len(), e8.grad.len(), "{name} grad len");
        for (g1, g8) in e1.grad.iter().zip(&e8.grad) {
            assert_eq!(g1.to_bits(), g8.to_bits(), "{name} grad");
        }
        assert_eq!(e1.mvms, e8.mvms, "{name} probe-column mvms");
    }
}

/// Block-solve contract: `cg_block` is bit-identical to column-by-column
/// scalar `cg_with_guess` — solutions, iteration counts, residuals,
/// convergence flags, and per-column MVM accounting — while never
/// executing more block-amortized applies than per-column MVMs.
fn assert_cg_block_matches(name: &str, op: &dyn LinOp, b: &Mat, x0: Option<&Mat>) {
    use gpsld::solvers::{cg_block, cg_with_guess, CgOptions};
    for bs in [1usize, 2, 3, 5, 8] {
        let opts = CgOptions { tol: 1e-10, max_iters: 150, block_size: bs, ..Default::default() };
        let (x, info) = cg_block(op, b, x0, &opts);
        assert_eq!(info.cols.len(), b.cols, "{name} bs={bs} info count");
        let mut col_mvms = 0;
        for j in 0..b.cols {
            let g = x0.map(|m| m.col(j));
            let (xs, si) = cg_with_guess(op, &b.col(j), g.as_deref(), &opts);
            for i in 0..b.rows {
                assert_eq!(
                    x[(i, j)].to_bits(),
                    xs[i].to_bits(),
                    "{name} bs={bs} x({i},{j}): {} vs {}",
                    x[(i, j)],
                    xs[i]
                );
            }
            let ci = &info.cols[j];
            assert_eq!(ci.iters, si.iters, "{name} bs={bs} col {j} iters");
            assert_eq!(ci.converged, si.converged, "{name} bs={bs} col {j} converged");
            assert_eq!(ci.mvms, si.mvms, "{name} bs={bs} col {j} mvms");
            assert_eq!(
                ci.residual.to_bits(),
                si.residual.to_bits(),
                "{name} bs={bs} col {j} residual: {} vs {}",
                ci.residual,
                si.residual
            );
            col_mvms += si.mvms;
        }
        assert_eq!(info.mvms, col_mvms, "{name} bs={bs} total mvms");
        assert!(
            info.block_applies <= info.mvms,
            "{name} bs={bs}: block applies {} > mvms {}",
            info.block_applies,
            info.mvms
        );
        if bs == 1 {
            assert_eq!(info.block_applies, info.mvms, "{name} bs=1 amortization");
        }
    }
}

/// Property (block-solve contract): block-CG matches scalar CG bit for bit
/// on every operator type — dense kernel, plain dense, shifted Toeplitz,
/// Kronecker, SKI (both diagonal-correction modes), grid Kron kernel,
/// FITC and SoR, additive sums, and the Laplace B wrapper — cold and
/// warm-started, at every block width.
#[test]
fn prop_cg_block_matches_scalar_cg() {
    let mut rng = Rng::new(1100);
    let n = 24;
    let k = 5;
    let pts1: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform_in(0.0, 2.0)]).collect();
    let pts2: Vec<Vec<f64>> =
        (0..n).map(|_| vec![rng.uniform(), rng.uniform()]).collect();
    let b = Mat::from_fn(n, k, |_, _| rng.gaussian());
    let x0 = Mat::from_fn(n, k, |_, _| 0.3 * rng.gaussian());

    // Dense kernel operator.
    let dense = DenseKernelOp::new(
        pts1.clone(),
        Box::new(IsoKernel::new(Shape::Matern32, 1, 0.4, 1.1)),
        0.3,
    );
    assert_cg_block_matches("dense_kernel", &dense, &b, None);
    assert_cg_block_matches("dense_kernel_warm", &dense, &b, Some(&x0));

    // Plain dense SPD matrix operator.
    let mut a = Mat::from_fn(n, n, |_, _| rng.gaussian());
    a.symmetrize();
    a.add_diag(n as f64);
    let dmat = DenseMatOp::new(a);
    assert_cg_block_matches("dense_mat", &dmat, &b, None);
    assert_cg_block_matches("dense_mat_warm", &dmat, &b, Some(&x0));

    // Shifted symmetric Toeplitz (exponential-decay kernel + "noise").
    let col: Vec<f64> =
        (0..n).map(|j| (1.5 + rng.uniform()) * (-0.1 * j as f64).exp()).collect();
    let top = ToeplitzOp::new(col);
    let shifted = gpsld::operators::ShiftedOp { inner: &top, shift: 1.0 };
    assert_cg_block_matches("toeplitz_shifted", &shifted, &b, None);

    // Kronecker (dense x toeplitz x dense), n = 2*4*3 = 24.
    let mut ka = Mat::from_fn(2, 2, |_, _| rng.gaussian());
    ka.symmetrize();
    ka.add_diag(2.0);
    let mut kc = Mat::from_fn(3, 3, |_, _| rng.gaussian());
    kc.symmetrize();
    kc.add_diag(3.0);
    let kron = KronOp::new(
        vec![
            KronFactor::Dense(ka),
            KronFactor::Toeplitz(ToeplitzOp::new(vec![2.0, 0.8, 0.1, 0.02])),
            KronFactor::Dense(kc),
        ],
        1.3,
    );
    assert_cg_block_matches("kron", &kron, &b, None);

    // SKI with and without the diagonal correction.
    for diag_corr in [false, true] {
        let grid = Grid::new(vec![GridDim { lo: -0.1, hi: 2.1, m: 16 }]);
        let ski = SkiOp::new(
            &pts1,
            grid,
            SeparableKernel::iso(Shape::Rbf, 1, 0.3, 1.0),
            0.2,
            InterpOrder::Cubic,
            diag_corr,
        );
        let name = if diag_corr { "ski_diag" } else { "ski" };
        assert_cg_block_matches(name, &ski, &b, None);
    }

    // Grid Kron kernel operator (W = I), n = 6*4 = 24.
    let grid2 = Grid::new(vec![
        GridDim { lo: 0.0, hi: 1.0, m: 6 },
        GridDim { lo: 0.0, hi: 1.0, m: 4 },
    ]);
    let kk = KronKernelOp::new(grid2, SeparableKernel::iso(Shape::Matern52, 2, 0.5, 0.9), 0.15);
    assert_cg_block_matches("kron_kernel", &kk, &b, None);

    // FITC and SoR.
    for fitc in [false, true] {
        let ind: Vec<Vec<f64>> = (0..6).map(|i| vec![2.0 * i as f64 / 5.0]).collect();
        let op = FitcOp::new(
            pts1.clone(),
            ind,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
            0.3,
            fitc,
        )
        .unwrap();
        let name = if fitc { "fitc" } else { "sor" };
        assert_cg_block_matches(name, &op, &b, None);
    }

    // Additive sum of two dense kernels.
    let p1 = DenseKernelOp::new(
        pts2.clone(),
        Box::new(IsoKernel::new(Shape::Rbf, 2, 0.5, 1.0)),
        1.0,
    );
    let p2 = DenseKernelOp::new(
        pts2.clone(),
        Box::new(IsoKernel::new(Shape::Matern12, 2, 0.8, 0.6)),
        1.0,
    );
    let sum = SumKernelOp::new(vec![Box::new(p1), Box::new(p2)], 0.4);
    assert_cg_block_matches("sum", &sum, &b, None);

    // Laplace B wrapper over the dense kernel (the Newton inner-solve op).
    let w: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
    let lb = gpsld::operators::LaplaceBOp::new(&dense, &w);
    assert_cg_block_matches("laplace_b", &lb, &b, None);
}

/// Thread-invariance contract: the RHS-group fan-out must be invisible in
/// the results — solutions, per-column statistics, and block-amortized
/// accounting bit-identical across `threads ∈ {1, 2, 8}`, cold and warm,
/// preconditioned and not.
fn assert_block_solve_thread_invariant(
    name: &str,
    op: &dyn LinOp,
    pc: Option<&dyn gpsld::solvers::Preconditioner>,
    b: &Mat,
    x0: Option<&Mat>,
) {
    use gpsld::solvers::{pcg_block, CgOptions};
    for bs in [1usize, 2, 3] {
        let base = CgOptions {
            tol: 1e-10,
            max_iters: 300,
            block_size: bs,
            threads: 1,
            ..Default::default()
        };
        let (x1, i1) = pcg_block(op, b, x0, pc, &base);
        for threads in [2usize, 8] {
            let opts = CgOptions { threads, ..base };
            let (xt, it) = pcg_block(op, b, x0, pc, &opts);
            for (a, c) in x1.data.iter().zip(&xt.data) {
                assert_eq!(
                    a.to_bits(),
                    c.to_bits(),
                    "{name} warm={} pc={} bs={bs} threads={threads}: {a} vs {c}",
                    x0.is_some(),
                    pc.is_some()
                );
            }
            assert_eq!(i1.mvms, it.mvms, "{name} bs={bs} threads={threads} mvms");
            assert_eq!(
                i1.block_applies, it.block_applies,
                "{name} bs={bs} threads={threads} applies"
            );
            for (j, (a, c)) in i1.cols.iter().zip(&it.cols).enumerate() {
                assert_eq!(a.iters, c.iters, "{name} bs={bs} threads={threads} col {j}");
                assert_eq!(a.converged, c.converged, "{name} col {j}");
                assert_eq!(a.mvms, c.mvms, "{name} col {j}");
                assert_eq!(a.residual.to_bits(), c.residual.to_bits(), "{name} col {j}");
            }
        }
    }
}

/// Property (thread invariance, solver level): `cg_block` / `pcg_block`
/// results are bit-identical across `threads ∈ {1, 2, 8}` for every
/// operator type, cold and warm, preconditioned (where the operator
/// exposes a diagonal) and not.
#[test]
fn prop_block_solves_thread_invariant() {
    use gpsld::solvers::{build_preconditioner, PrecondOptions, Preconditioner};
    let mut rng = Rng::new(1500);
    let n = 24;
    let k = 7;
    let pts1: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform_in(0.0, 2.0)]).collect();
    let b = Mat::from_fn(n, k, |_, _| rng.gaussian());
    let x0 = Mat::from_fn(n, k, |_, _| 0.3 * rng.gaussian());

    // Dense kernel — cold, warm, and preconditioned.
    let dense = DenseKernelOp::new(
        pts1.clone(),
        Box::new(IsoKernel::new(Shape::Matern32, 1, 0.4, 1.1)),
        0.2,
    );
    assert_block_solve_thread_invariant("dense_kernel", &dense, None, &b, None);
    assert_block_solve_thread_invariant("dense_kernel_warm", &dense, None, &b, Some(&x0));
    let pc = build_preconditioner(&dense, PrecondOptions::rank(8)).unwrap();
    let pcd = Some(&pc as &dyn Preconditioner);
    assert_block_solve_thread_invariant("dense_kernel_pcg", &dense, pcd, &b, None);
    assert_block_solve_thread_invariant("dense_kernel_pcg_warm", &dense, pcd, &b, Some(&x0));

    // Plain dense SPD matrix.
    let mut a = Mat::from_fn(n, n, |_, _| rng.gaussian());
    a.symmetrize();
    a.add_diag(n as f64);
    let dmat = DenseMatOp::new(a);
    assert_block_solve_thread_invariant("dense_mat", &dmat, None, &b, None);

    // Shifted symmetric Toeplitz.
    let col: Vec<f64> =
        (0..n).map(|j| (1.5 + rng.uniform()) * (-0.1 * j as f64).exp()).collect();
    let top = ToeplitzOp::new(col);
    let shifted = gpsld::operators::ShiftedOp { inner: &top, shift: 1.0 };
    assert_block_solve_thread_invariant("toeplitz_shifted", &shifted, None, &b, None);

    // Kronecker (dense x toeplitz x dense), n = 2*4*3 = 24.
    let mut ka = Mat::from_fn(2, 2, |_, _| rng.gaussian());
    ka.symmetrize();
    ka.add_diag(2.0);
    let mut kc = Mat::from_fn(3, 3, |_, _| rng.gaussian());
    kc.symmetrize();
    kc.add_diag(3.0);
    let kron = KronOp::new(
        vec![
            KronFactor::Dense(ka),
            KronFactor::Toeplitz(ToeplitzOp::new(vec![2.0, 0.8, 0.1, 0.02])),
            KronFactor::Dense(kc),
        ],
        1.3,
    );
    assert_block_solve_thread_invariant("kron", &kron, None, &b, None);

    // SKI (both diagonal-correction modes), preconditioned too.
    for diag_corr in [false, true] {
        let grid = Grid::new(vec![GridDim { lo: -0.1, hi: 2.1, m: 16 }]);
        let ski = SkiOp::new(
            &pts1,
            grid,
            SeparableKernel::iso(Shape::Rbf, 1, 0.3, 1.0),
            0.2,
            InterpOrder::Cubic,
            diag_corr,
        );
        let name = if diag_corr { "ski_diag" } else { "ski" };
        assert_block_solve_thread_invariant(name, &ski, None, &b, None);
        let pc = build_preconditioner(&ski, PrecondOptions::rank(6)).unwrap();
        assert_block_solve_thread_invariant(
            name,
            &ski,
            Some(&pc as &dyn Preconditioner),
            &b,
            Some(&x0),
        );
    }

    // Grid Kron kernel (W = I), n = 6*4 = 24.
    let grid2 = Grid::new(vec![
        GridDim { lo: 0.0, hi: 1.0, m: 6 },
        GridDim { lo: 0.0, hi: 1.0, m: 4 },
    ]);
    let kk = KronKernelOp::new(grid2, SeparableKernel::iso(Shape::Matern52, 2, 0.5, 0.9), 0.15);
    assert_block_solve_thread_invariant("kron_kernel", &kk, None, &b, None);

    // FITC and SoR.
    for fitc in [false, true] {
        let ind: Vec<Vec<f64>> = (0..6).map(|i| vec![2.0 * i as f64 / 5.0]).collect();
        let op = FitcOp::new(
            pts1.clone(),
            ind,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
            0.3,
            fitc,
        )
        .unwrap();
        let name = if fitc { "fitc" } else { "sor" };
        assert_block_solve_thread_invariant(name, &op, None, &b, None);
    }

    // Additive sum of two dense kernels.
    let pts2: Vec<Vec<f64>> =
        (0..n).map(|_| vec![rng.uniform(), rng.uniform()]).collect();
    let p1 = DenseKernelOp::new(
        pts2.clone(),
        Box::new(IsoKernel::new(Shape::Rbf, 2, 0.5, 1.0)),
        1.0,
    );
    let p2 = DenseKernelOp::new(
        pts2.clone(),
        Box::new(IsoKernel::new(Shape::Matern12, 2, 0.8, 0.6)),
        1.0,
    );
    let sum = SumKernelOp::new(vec![Box::new(p1), Box::new(p2)], 0.4);
    assert_block_solve_thread_invariant("sum", &sum, None, &b, None);

    // Laplace B wrapper (the Newton inner-solve operator).
    let w: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
    let lb = gpsld::operators::LaplaceBOp::new(&dense, &w);
    assert_block_solve_thread_invariant("laplace_b", &lb, None, &b, None);
}

/// Property (thread invariance, operator level, ABOVE the internal
/// threading gates): the small-n solver/estimator invariance tests never
/// reach the operators' own parallel paths (dense engages at
/// `n·n·b >= 4e6`, Toeplitz at `fft_work·b >= 250e3`), so this case
/// drives `apply_mat` past both thresholds and pins the blocked result
/// bit-identical across process-default thread counts — the composition
/// the worker thread-budget guard newly enables (operator threads running
/// under group workers) must never change per-element accumulation.
/// (Integration tests run in their own process, so pinning the process
/// default here cannot race the lib tests' default-mutating cases.)
#[test]
fn prop_operator_internal_threading_bit_invariant() {
    use gpsld::util::parallel::with_default_threads;
    let mut rng = Rng::new(1700);

    // Dense kernel above the 4M-entry gate: n² · b = 1024² · 4 ≈ 4.2M.
    let n = 1024;
    let pts: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.gaussian()]).collect();
    let dense = DenseKernelOp::new(
        pts,
        Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
        0.3,
    );
    let x = Mat::from_fn(n, 4, |_, _| rng.gaussian());
    let y1 = with_default_threads(1, || dense.apply_mat(&x));
    let y8 = with_default_threads(8, || dense.apply_mat(&x));
    for (a, c) in y1.data.iter().zip(&y8.data) {
        assert_eq!(a.to_bits(), c.to_bits(), "dense threaded apply_mat drifted");
    }
    // And the threaded block path still matches the single-vector path
    // column-for-column, bitwise (the PR 1 column-independence contract).
    for j in 0..4 {
        let col = dense.apply_vec(&x.col(j));
        for i in 0..n {
            assert_eq!(y8[(i, j)].to_bits(), col[i].to_bits(), "dense col {j}");
        }
    }

    // Toeplitz above the FFT-work gate: len·log2(len)·b ≈ 4096·12·8 ≈ 393k.
    let tcol: Vec<f64> = (0..2048).map(|k| (-0.001 * k as f64).exp()).collect();
    let top = ToeplitzOp::new(tcol);
    let xt = Mat::from_fn(2048, 8, |_, _| rng.gaussian());
    let t1 = with_default_threads(1, || top.apply_mat(&xt));
    let t8 = with_default_threads(8, || top.apply_mat(&xt));
    for (a, c) in t1.data.iter().zip(&t8.data) {
        assert_eq!(a.to_bits(), c.to_bits(), "toeplitz threaded apply_mat drifted");
    }
}

/// Property (thread invariance, estimator level): SLQ and Chebyshev
/// estimates — values, std errors, gradients, per-probe vectors, and MVM
/// accounting — are bit-identical across `threads ∈ {1, 2, 8}`, plain and
/// preconditioned, on dense and structured operators.
#[test]
fn prop_estimators_thread_invariant() {
    use gpsld::estimators::chebyshev::{chebyshev_logdet, ChebOptions};
    use gpsld::estimators::slq::{slq_logdet, slq_logdet_pc, SlqOptions};
    use gpsld::solvers::{build_preconditioner, PrecondOptions, Preconditioner};
    let mut rng = Rng::new(1600);
    let n = 60;
    let pts: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
    let grid = Grid::covering(&pts, &[32], 0.1);
    let ski = SkiOp::new(
        &pts,
        grid,
        SeparableKernel::iso(Shape::Rbf, 1, 0.3, 1.0),
        0.2,
        InterpOrder::Cubic,
        false,
    );
    let dense = DenseKernelOp::new(
        pts.clone(),
        Box::new(IsoKernel::new(Shape::Rbf, 1, 0.3, 1.0)),
        0.2,
    );
    for (name, op) in [("dense", &dense as &dyn KernelOp), ("ski", &ski)] {
        // Small block size so 8 probes span several blocks to fan out.
        let s1 = slq_logdet(
            op,
            &SlqOptions { steps: 15, probes: 8, seed: 5, block_size: 2, threads: 1, ..Default::default() },
        )
        .unwrap();
        let c1 = chebyshev_logdet(
            op,
            &ChebOptions {
                degree: 25,
                probes: 8,
                seed: 5,
                lambda_bounds: Some((0.02, 40.0)),
                block_size: 2,
                threads: 1,
                ..Default::default()
            },
        )
        .unwrap();
        for threads in [2usize, 8] {
            let st = slq_logdet(
                op,
                &SlqOptions { steps: 15, probes: 8, seed: 5, block_size: 2, threads, ..Default::default() },
            )
            .unwrap();
            assert_eq!(s1.value.to_bits(), st.value.to_bits(), "{name} slq t={threads}");
            assert_eq!(s1.std_err.to_bits(), st.std_err.to_bits(), "{name} slq se");
            assert_eq!(s1.mvms, st.mvms, "{name} slq mvms");
            assert_eq!(s1.block_applies, st.block_applies, "{name} slq applies");
            for (a, c) in s1.grad.iter().zip(&st.grad) {
                assert_eq!(a.to_bits(), c.to_bits(), "{name} slq grad t={threads}");
            }
            for (a, c) in s1.per_probe.iter().zip(&st.per_probe) {
                assert_eq!(a.to_bits(), c.to_bits(), "{name} slq per-probe t={threads}");
            }
            let ct = chebyshev_logdet(
                op,
                &ChebOptions {
                    degree: 25,
                    probes: 8,
                    seed: 5,
                    lambda_bounds: Some((0.02, 40.0)),
                    block_size: 2,
                    threads,
                    ..Default::default()
                },
            )
            .unwrap();
            assert_eq!(c1.value.to_bits(), ct.value.to_bits(), "{name} cheb t={threads}");
            assert_eq!(c1.std_err.to_bits(), ct.std_err.to_bits(), "{name} cheb se");
            assert_eq!(c1.mvms, ct.mvms, "{name} cheb mvms");
            for (a, c) in c1.grad.iter().zip(&ct.grad) {
                assert_eq!(a.to_bits(), c.to_bits(), "{name} cheb grad t={threads}");
            }
        }
    }
    // Preconditioned SLQ is thread-invariant too.
    let pc = build_preconditioner(&dense, PrecondOptions::rank(8)).unwrap();
    let pcd = Some(&pc as &dyn Preconditioner);
    let p1 = slq_logdet_pc(
        &dense,
        pcd,
        &SlqOptions { steps: 15, probes: 8, seed: 9, block_size: 2, threads: 1, ..Default::default() },
    )
    .unwrap();
    for threads in [2usize, 8] {
        let pt = slq_logdet_pc(
            &dense,
            pcd,
            &SlqOptions { steps: 15, probes: 8, seed: 9, block_size: 2, threads, ..Default::default() },
        )
        .unwrap();
        assert_eq!(p1.value.to_bits(), pt.value.to_bits(), "pc slq t={threads}");
        for (a, c) in p1.grad.iter().zip(&pt.grad) {
            assert_eq!(a.to_bits(), c.to_bits(), "pc slq grad t={threads}");
        }
    }
}

/// Property (true-residual convergence): whenever CG reports `converged`,
/// the *recomputed* true residual honors the tolerance — the recurrence
/// residual alone is not trusted.
#[test]
fn prop_cg_converged_implies_true_residual() {
    use gpsld::solvers::{cg_block, CgOptions};
    use gpsld::util::stats::norm2;
    let mut rng = Rng::new(1200);
    for case in 0..10 {
        let n = 20 + rng.below(40);
        let pts: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
        let op = DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(rand_shape(&mut rng), 1, 0.2 + rng.uniform(), 1.0)),
            0.05 + 0.3 * rng.uniform(),
        );
        let b = Mat::from_fn(n, 3, |_, _| rng.gaussian());
        let opts = CgOptions { tol: 1e-9, max_iters: 4 * n, block_size: 3, ..Default::default() };
        let (x, info) = cg_block(&op, &b, None, &opts);
        for j in 0..3 {
            let ci = &info.cols[j];
            if !ci.converged {
                continue;
            }
            let ax = op.apply_vec(&x.col(j));
            let bj = b.col(j);
            let rtrue: Vec<f64> = (0..n).map(|i| bj[i] - ax[i]).collect();
            let rel = norm2(&rtrue) / norm2(&bj);
            assert!(
                rel <= opts.tol * (1.0 + 1e-12),
                "case {case} col {j}: converged but true residual {rel}"
            );
        }
    }
}

/// Preconditioning contract: `pcg`/`pcg_block` with a rank-r pivoted-
/// Cholesky preconditioner converge to the same solution as the
/// unpreconditioned `cg` reference (both at the same tolerance), the block
/// engine stays bit-identical to scalar PCG per column at every block
/// width, and `pc = None` is bit-identical to the unpreconditioned path.
fn assert_pcg_matches_cg(name: &str, op: &dyn KernelOp, b: &Mat, rank: usize) {
    use gpsld::solvers::{
        build_preconditioner, cg_with_guess, pcg_block, pcg_with_guess, CgOptions,
        PrecondOptions, Preconditioner,
    };
    let opts = CgOptions { tol: 1e-10, max_iters: 2000, block_size: 3, ..Default::default() };
    let pc = build_preconditioner(op, PrecondOptions::rank(rank))
        .unwrap_or_else(|| panic!("{name}: operator should support preconditioning"));
    let pcd = Some(&pc as &dyn Preconditioner);
    // Unpreconditioned reference solutions.
    let refs: Vec<(Vec<f64>, bool)> = (0..b.cols)
        .map(|j| {
            let (x, i) = cg_with_guess(op, &b.col(j), None, &opts);
            (x, i.converged)
        })
        .collect();
    // pc = None must be the cg code path, bit for bit.
    for j in 0..b.cols {
        let (x, _) = pcg_with_guess(op, &b.col(j), None, None, &opts);
        for i in 0..b.rows {
            assert_eq!(x[i].to_bits(), refs[j].0[i].to_bits(), "{name} none-path ({i},{j})");
        }
    }
    for bs in [1usize, 2, 5] {
        let bopts = CgOptions { block_size: bs, ..opts };
        let (xb, info) = pcg_block(op, b, None, pcd, &bopts);
        assert!(info.block_applies <= info.mvms, "{name} bs={bs} accounting");
        for j in 0..b.cols {
            // Block PCG is bit-identical to scalar PCG on the column.
            let (xs, si) = pcg_with_guess(op, &b.col(j), None, pcd, &bopts);
            for i in 0..b.rows {
                assert_eq!(
                    xb[(i, j)].to_bits(),
                    xs[i].to_bits(),
                    "{name} bs={bs} pcg block!=scalar ({i},{j})"
                );
            }
            assert_eq!(info.cols[j].iters, si.iters, "{name} bs={bs} col {j} iters");
            assert_eq!(info.cols[j].converged, si.converged, "{name} bs={bs} col {j}");
            // And agrees with the unpreconditioned solution within the
            // (shared) solver tolerance.
            if si.converged && refs[j].1 {
                let scale: f64 =
                    refs[j].0.iter().map(|v| v.abs()).fold(0.0, f64::max).max(1.0);
                for i in 0..b.rows {
                    assert!(
                        (xs[i] - refs[j].0[i]).abs() <= 1e-5 * scale,
                        "{name} bs={bs} col {j} row {i}: {} vs {}",
                        xs[i],
                        refs[j].0[i]
                    );
                }
            }
        }
    }
}

/// Property (preconditioning): PCG solutions match plain CG for every
/// operator type that exposes its diagonal — dense kernel, SKI (both
/// diagonal-correction modes), the grid Kron kernel, FITC and SoR, and
/// additive sums — with the block engine bit-identical to scalar PCG.
#[test]
fn prop_pcg_matches_cg_all_operator_types() {
    let mut rng = Rng::new(1300);
    let n = 24;
    let k = 4;
    let pts1: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform_in(0.0, 2.0)]).collect();
    let pts2: Vec<Vec<f64>> =
        (0..n).map(|_| vec![rng.uniform(), rng.uniform()]).collect();
    let b = Mat::from_fn(n, k, |_, _| rng.gaussian());

    let dense = DenseKernelOp::new(
        pts1.clone(),
        Box::new(IsoKernel::new(Shape::Matern32, 1, 0.4, 1.1)),
        0.15,
    );
    assert_pcg_matches_cg("dense_kernel", &dense, &b, 8);

    for diag_corr in [false, true] {
        let grid = Grid::new(vec![GridDim { lo: -0.1, hi: 2.1, m: 16 }]);
        let ski = SkiOp::new(
            &pts1,
            grid,
            SeparableKernel::iso(Shape::Rbf, 1, 0.3, 1.0),
            0.2,
            InterpOrder::Cubic,
            diag_corr,
        );
        let name = if diag_corr { "ski_diag" } else { "ski" };
        assert_pcg_matches_cg(name, &ski, &b, 8);
    }

    let grid2 = Grid::new(vec![
        GridDim { lo: 0.0, hi: 1.0, m: 6 },
        GridDim { lo: 0.0, hi: 1.0, m: 4 },
    ]);
    let kk = KronKernelOp::new(grid2, SeparableKernel::iso(Shape::Matern52, 2, 0.5, 0.9), 0.15);
    assert_pcg_matches_cg("kron_kernel", &kk, &b, 8);

    for fitc in [false, true] {
        let ind: Vec<Vec<f64>> = (0..6).map(|i| vec![2.0 * i as f64 / 5.0]).collect();
        let op = FitcOp::new(
            pts1.clone(),
            ind,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
            0.3,
            fitc,
        )
        .unwrap();
        let name = if fitc { "fitc" } else { "sor" };
        assert_pcg_matches_cg(name, &op, &b, 6);
    }

    let p1 = DenseKernelOp::new(
        pts2.clone(),
        Box::new(IsoKernel::new(Shape::Rbf, 2, 0.5, 1.0)),
        1.0,
    );
    let p2 = DenseKernelOp::new(
        pts2.clone(),
        Box::new(IsoKernel::new(Shape::Matern12, 2, 0.8, 0.6)),
        1.0,
    );
    let sum = SumKernelOp::new(vec![Box::new(p1), Box::new(p2)], 0.4);
    assert_pcg_matches_cg("sum", &sum, &b, 8);
}

/// Property (preconditioned SLQ): the stochastic estimate on the split
/// operator plus the exact log|P| reproduces the exact log determinant on
/// small random matrices (full-depth Lanczos makes the per-probe
/// quadrature exact; the flattened spectrum makes the probe variance
/// tiny).
#[test]
fn prop_preconditioned_slq_matches_exact_logdet() {
    use gpsld::estimators::exact;
    use gpsld::estimators::slq::{slq_logdet_pc, SlqOptions};
    use gpsld::solvers::{build_preconditioner, PrecondOptions, Preconditioner};
    let mut rng = Rng::new(1400);
    for case in 0..5 {
        let n = 40 + rng.below(40);
        let sigma = 0.05 + 0.2 * rng.uniform();
        let pts: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
        let op = DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(rand_shape(&mut rng), 1, 0.4, 1.0)),
            sigma,
        );
        let truth = exact::exact_logdet(&op).unwrap();
        let pc = build_preconditioner(&op, PrecondOptions::rank(16)).unwrap();
        let est = slq_logdet_pc(
            &op,
            Some(&pc as &dyn Preconditioner),
            &SlqOptions {
                steps: n,
                probes: 8,
                grads: false,
                seed: 7000 + case,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(
            (est.value - truth).abs() < 4.0 * est.std_err + 0.02 * truth.abs().max(1.0),
            "case {case}: {} vs {truth} (se {})",
            est.value,
            est.std_err
        );
    }
}

/// Builds one instance of every operator type (n = 24 throughout) and
/// hands each to `f` — the shared fixture for the precision-contract
/// properties below, covering both operators with dedicated f32 panels
/// (dense, CSR/SKI, Toeplitz staging, sums, the shifted/Laplace/
/// preconditioned wrappers that forward the knob) and operators that
/// fall through to the exact-f64 trait default (FITC, grid Kron kernel).
fn for_each_precision_op(f: &mut dyn FnMut(&str, &dyn LinOp)) {
    use gpsld::solvers::{build_preconditioner, PrecondOptions, PreconditionedOp};
    let mut rng = Rng::new(2100);
    let n = 24;
    let pts1: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform_in(0.0, 2.0)]).collect();
    let pts2: Vec<Vec<f64>> =
        (0..n).map(|_| vec![rng.uniform(), rng.uniform()]).collect();

    let dense = DenseKernelOp::new(
        pts1.clone(),
        Box::new(IsoKernel::new(Shape::Matern32, 1, 0.4, 1.1)),
        0.2,
    );
    f("dense_kernel", &dense);

    let mut a = Mat::from_fn(n, n, |_, _| rng.gaussian());
    a.symmetrize();
    a.add_diag(n as f64);
    let dmat = DenseMatOp::new(a);
    f("dense_mat", &dmat);

    let col: Vec<f64> =
        (0..n).map(|k| (1.5 + rng.uniform()) * (-0.1 * k as f64).exp()).collect();
    let top = ToeplitzOp::new(col);
    f("toeplitz", &top);
    let shifted = gpsld::operators::ShiftedOp { inner: &top, shift: 1.0 };
    f("toeplitz_shifted", &shifted);

    let mut ka = Mat::from_fn(2, 2, |_, _| rng.gaussian());
    ka.symmetrize();
    ka.add_diag(2.0);
    let mut kc = Mat::from_fn(3, 3, |_, _| rng.gaussian());
    kc.symmetrize();
    kc.add_diag(3.0);
    let kron = KronOp::new(
        vec![
            KronFactor::Dense(ka),
            KronFactor::Toeplitz(ToeplitzOp::new(vec![2.0, 0.8, 0.1, 0.02])),
            KronFactor::Dense(kc),
        ],
        1.3,
    );
    f("kron", &kron);

    for diag_corr in [false, true] {
        let grid = Grid::new(vec![GridDim { lo: -0.1, hi: 2.1, m: 16 }]);
        let ski = SkiOp::new(
            &pts1,
            grid,
            SeparableKernel::iso(Shape::Rbf, 1, 0.3, 1.0),
            0.2,
            InterpOrder::Cubic,
            diag_corr,
        );
        f(if diag_corr { "ski_diag" } else { "ski" }, &ski);
    }

    let grid2 = Grid::new(vec![
        GridDim { lo: 0.0, hi: 1.0, m: 6 },
        GridDim { lo: 0.0, hi: 1.0, m: 4 },
    ]);
    let kk = KronKernelOp::new(grid2, SeparableKernel::iso(Shape::Matern52, 2, 0.5, 0.9), 0.15);
    f("kron_kernel", &kk);

    for fitc in [false, true] {
        let ind: Vec<Vec<f64>> = (0..6).map(|i| vec![2.0 * i as f64 / 5.0]).collect();
        let op = FitcOp::new(
            pts1.clone(),
            ind,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
            0.3,
            fitc,
        )
        .unwrap();
        f(if fitc { "fitc" } else { "sor" }, &op);
    }

    let p1 = DenseKernelOp::new(
        pts2.clone(),
        Box::new(IsoKernel::new(Shape::Rbf, 2, 0.5, 1.0)),
        1.0,
    );
    let p2 = DenseKernelOp::new(
        pts2.clone(),
        Box::new(IsoKernel::new(Shape::Matern12, 2, 0.8, 0.6)),
        1.0,
    );
    let sum = SumKernelOp::new(vec![Box::new(p1), Box::new(p2)], 0.4);
    f("sum", &sum);

    let w: Vec<f64> = (0..n).map(|_| rng.uniform()).collect();
    let lb = gpsld::operators::LaplaceBOp::new(&dense, &w);
    f("laplace_b", &lb);

    let pc = build_preconditioner(&dense, PrecondOptions::rank(6)).unwrap();
    let pop = PreconditionedOp::new(&dense, &pc);
    f("preconditioned_split", &pop);
}

/// Property (precision contract, F64 arm): `apply_mat_prec(x, F64)` is
/// bit-identical to `apply_mat(x)` for every operator type at block
/// widths 1 and 8, and a block solve with `precision: F64` pinned
/// explicitly is bit-identical — solutions, per-column statistics, MVM
/// accounting — to one using the defaulted options. Threading the
/// precision knob through must leave the f64 paths untouched.
#[test]
fn prop_precision_f64_identity_all_ops() {
    use gpsld::solvers::{cg_block, CgOptions};
    for_each_precision_op(&mut |name, op| {
        let n = op.n();
        let mut rng = Rng::new(2200);
        for bcols in [1usize, 8] {
            let x = Mat::from_fn(n, bcols, |_, _| rng.gaussian());
            let y = op.apply_mat(&x);
            let yp = op.apply_mat_prec(&x, Precision::F64);
            assert_eq!((yp.rows, yp.cols), (y.rows, y.cols), "{name} b={bcols} shape");
            for (a, c) in y.data.iter().zip(&yp.data) {
                assert_eq!(a.to_bits(), c.to_bits(), "{name} b={bcols}: {a} vs {c}");
            }
        }
        let b = Mat::from_fn(n, 3, |_, _| rng.gaussian());
        let base = CgOptions { tol: 1e-9, max_iters: 200, block_size: 2, ..Default::default() };
        let pinned = CgOptions {
            tol: 1e-9,
            max_iters: 200,
            block_size: 2,
            precision: Precision::F64,
            ..Default::default()
        };
        let (x1, i1) = cg_block(op, &b, None, &base);
        let (x2, i2) = cg_block(op, &b, None, &pinned);
        for (a, c) in x1.data.iter().zip(&x2.data) {
            assert_eq!(a.to_bits(), c.to_bits(), "{name} solve: {a} vs {c}");
        }
        assert_eq!(i1.mvms, i2.mvms, "{name} solve mvms");
        assert_eq!(i1.block_applies, i2.block_applies, "{name} solve applies");
        for (j, (a, c)) in i1.cols.iter().zip(&i2.cols).enumerate() {
            assert_eq!(a.iters, c.iters, "{name} solve col {j} iters");
            assert_eq!(a.converged, c.converged, "{name} solve col {j} converged");
            assert_eq!(a.residual.to_bits(), c.residual.to_bits(), "{name} solve col {j}");
        }
    });
}

/// Property (precision contract, mixed arm): the F32F64 apply differs
/// from f64 by at most a forward-error bound scaled like
/// `eps_f32 · (‖x‖₁ + ‖y‖∞)` — the only loss is one f32 storage rounding
/// per operator entry (or per staged value), accumulated in f64. Ops
/// without an f32 panel fall through to exact f64 (zero difference,
/// which the bound also accepts); for the dense panels the difference
/// must be *nonzero*, proving the knob actually reaches storage.
#[test]
fn prop_precision_mixed_apply_error_bound() {
    let eps32 = f64::from(f32::EPSILON);
    for_each_precision_op(&mut |name, op| {
        let n = op.n();
        let mut rng = Rng::new(2300);
        for bcols in [1usize, 8] {
            let x = Mat::from_fn(n, bcols, |_, _| rng.gaussian());
            let y = op.apply_mat(&x);
            let ym = op.apply_mat_prec(&x, Precision::F32F64);
            assert_eq!((ym.rows, ym.cols), (y.rows, y.cols), "{name} b={bcols} shape");
            let mut max_diff = 0.0f64;
            for j in 0..bcols {
                let x_l1: f64 = (0..n).map(|i| x[(i, j)].abs()).sum();
                let y_inf: f64 = (0..n).map(|i| y[(i, j)].abs()).fold(0.0, f64::max);
                let tol = 64.0 * eps32 * (1.0 + x_l1 + y_inf);
                for i in 0..n {
                    let d = (ym[(i, j)] - y[(i, j)]).abs();
                    max_diff = max_diff.max(d);
                    assert!(
                        d <= tol,
                        "{name} b={bcols} ({i},{j}): |{} - {}| = {d} > {tol}",
                        ym[(i, j)],
                        y[(i, j)]
                    );
                }
            }
            // Ops with an f32 panel must actually move at f32 scale: the
            // dense panels round the full matrix, the FITC/SoR panels
            // round the low-rank cross factor both ways.
            if bcols == 8
                && (name == "dense_kernel"
                    || name == "dense_mat"
                    || name == "fitc"
                    || name == "sor")
            {
                assert!(max_diff > 0.0, "{name}: mixed apply identical to f64 — knob inert");
            }
        }
    });
}

/// Property (precision contract, refinement arm): a block solve in
/// F32F64 mode that reports `converged` meets the *f64* tolerance — the
/// recomputed full-precision true residual honors `tol` — for dense,
/// Toeplitz, SKI, and sum operators, cold and warm-started, CG and PCG.
/// Mixed inner iterations plus f64 confirmation/restart (iterative
/// refinement) must never weaken what convergence asserts.
#[test]
fn prop_precision_refinement_meets_f64_tol() {
    use gpsld::solvers::{
        build_preconditioner, cg_block, pcg_block, CgOptions, PrecondOptions, Preconditioner,
    };
    use gpsld::util::stats::norm2;
    let mut rng = Rng::new(2400);
    let n = 24;
    let k = 4;
    let pts: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform_in(0.0, 2.0)]).collect();
    let b = Mat::from_fn(n, k, |_, _| rng.gaussian());
    let x0 = Mat::from_fn(n, k, |_, _| 0.3 * rng.gaussian());
    let opts = CgOptions {
        tol: 1e-8,
        max_iters: 800,
        block_size: 2,
        precision: Precision::F32F64,
        ..Default::default()
    };
    let check = |name: &str, op: &dyn LinOp, x: &Mat, info: &gpsld::solvers::BlockCgInfo| {
        for j in 0..k {
            assert!(info.cols[j].converged, "{name} col {j} failed to converge");
            let ax = op.apply_vec(&x.col(j));
            let bj = b.col(j);
            let rtrue: Vec<f64> = (0..n).map(|i| bj[i] - ax[i]).collect();
            let rel = norm2(&rtrue) / norm2(&bj);
            assert!(
                rel <= opts.tol * (1.0 + 1e-12),
                "{name} col {j}: converged in mixed mode but f64 residual {rel}"
            );
        }
    };

    let dense = DenseKernelOp::new(
        pts.clone(),
        Box::new(IsoKernel::new(Shape::Matern32, 1, 0.4, 1.1)),
        0.3,
    );
    let col: Vec<f64> =
        (0..n).map(|j| (1.5 + rng.uniform()) * (-0.1 * j as f64).exp()).collect();
    let top = ToeplitzOp::new(col);
    let shifted = gpsld::operators::ShiftedOp { inner: &top, shift: 1.0 };
    let grid = Grid::new(vec![GridDim { lo: -0.1, hi: 2.1, m: 16 }]);
    let ski = SkiOp::new(
        &pts,
        grid,
        SeparableKernel::iso(Shape::Rbf, 1, 0.3, 1.0),
        0.2,
        InterpOrder::Cubic,
        false,
    );
    let s1 = DenseKernelOp::new(
        pts.clone(),
        Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
        1.0,
    );
    let s2 = DenseKernelOp::new(
        pts.clone(),
        Box::new(IsoKernel::new(Shape::Matern12, 1, 0.8, 0.6)),
        1.0,
    );
    let sum = SumKernelOp::new(vec![Box::new(s1), Box::new(s2)], 0.4);

    for (name, op) in [
        ("dense_kernel", &dense as &dyn LinOp),
        ("toeplitz_shifted", &shifted),
        ("ski", &ski),
        ("sum", &sum),
    ] {
        for (warm, guess) in [("cold", None), ("warm", Some(&x0))] {
            let (x, info) = cg_block(op, &b, guess, &opts);
            check(&format!("{name}_{warm}"), op, &x, &info);
        }
    }

    // PCG: mixed inner applies on the preconditioned system, convergence
    // still declared on the unpreconditioned f64 residual.
    let pc = build_preconditioner(&dense, PrecondOptions::rank(6)).unwrap();
    for (warm, guess) in [("cold", None), ("warm", Some(&x0))] {
        let (x, info) =
            pcg_block(&dense, &b, guess, Some(&pc as &dyn Preconditioner), &opts);
        check(&format!("dense_pcg_{warm}"), &dense, &x, &info);
    }
}

/// Compare every output of two logdet estimates bitwise (the fixed-budget
/// preservation contract of the evidence refactor).
fn assert_estimates_bit_identical(
    name: &str,
    a: &gpsld::estimators::LogdetEstimate,
    b: &gpsld::estimators::LogdetEstimate,
) {
    assert_eq!(a.value.to_bits(), b.value.to_bits(), "{name} value: {} vs {}", a.value, b.value);
    assert_eq!(a.std_err.to_bits(), b.std_err.to_bits(), "{name} std_err");
    assert_eq!(a.grad.len(), b.grad.len(), "{name} grad len");
    for (x, y) in a.grad.iter().zip(&b.grad) {
        assert_eq!(x.to_bits(), y.to_bits(), "{name} grad");
    }
    assert_eq!(a.per_probe.len(), b.per_probe.len(), "{name} per_probe len");
    for (x, y) in a.per_probe.iter().zip(&b.per_probe) {
        assert_eq!(x.to_bits(), y.to_bits(), "{name} per_probe");
    }
    assert_eq!(a.mvms, b.mvms, "{name} mvms");
    assert_eq!(a.block_applies, b.block_applies, "{name} block_applies");
    assert_eq!(a.probes_used, b.probes_used, "{name} probes_used");
    assert_eq!(a.steps_used, b.steps_used, "{name} steps_used");
}

/// Property (evidence refactor, fixed-budget preservation): with
/// `target_tol` unset, the adaptive knobs (`max_probes` / `max_steps`)
/// are bitwise inert — every estimator output (value, grad, std_err,
/// per_probe, mvms, block_applies, probes/steps accounting) matches the
/// plain fixed-budget options — for dense and SKI operators, at block
/// sizes {1, 3, 8}, thread counts {1, 4}, and both MVM precisions.
#[test]
fn prop_adaptive_knobs_inert_when_tol_unset() {
    use gpsld::estimators::chebyshev::{chebyshev_logdet, ChebOptions};
    use gpsld::estimators::slq::{slq_logdet, SlqOptions};
    let mut rng = Rng::new(2500);
    let n = 60;
    let pts: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
    let grid = Grid::covering(&pts, &[32], 0.1);
    let ski = SkiOp::new(
        &pts,
        grid,
        SeparableKernel::iso(Shape::Rbf, 1, 0.3, 1.0),
        0.2,
        InterpOrder::Cubic,
        false,
    );
    let dense = DenseKernelOp::new(
        pts.clone(),
        Box::new(IsoKernel::new(Shape::Rbf, 1, 0.3, 1.0)),
        0.2,
    );
    for (name, op) in [("dense", &dense as &dyn KernelOp), ("ski", &ski)] {
        for bs in [1usize, 3, 8] {
            for threads in [1usize, 4] {
                for prec in [Precision::F64, Precision::F32F64] {
                    let slq_fixed = SlqOptions {
                        steps: 15,
                        probes: 8,
                        seed: 11,
                        block_size: bs,
                        threads,
                        precision: prec,
                        target_tol: None,
                        ..Default::default()
                    };
                    let slq_knobs = SlqOptions {
                        max_probes: 3, // below the fixed budget — must not truncate it
                        max_steps: 2,  // below the fixed steps — must not cap them
                        ..slq_fixed
                    };
                    let a = slq_logdet(op, &slq_fixed).unwrap();
                    let b = slq_logdet(op, &slq_knobs).unwrap();
                    assert_estimates_bit_identical(
                        &format!("{name} slq bs={bs} t={threads} {:?}", prec),
                        &a,
                        &b,
                    );
                    // Fixed-budget paths never carry session resume
                    // handles — only the two-axis adaptive driver does.
                    for est in [&a, &b] {
                        match &est.evidence {
                            gpsld::estimators::SpectralEvidence::Lanczos {
                                resume, ..
                            } => assert!(resume.is_none(), "{name} fixed slq resume"),
                            other => panic!("slq evidence variant: {other:?}"),
                        }
                    }
                    let cheb_fixed = ChebOptions {
                        degree: 25,
                        probes: 8,
                        seed: 11,
                        lambda_bounds: Some((0.02, 40.0)),
                        block_size: bs,
                        threads,
                        precision: prec,
                        target_tol: None,
                        ..Default::default()
                    };
                    let cheb_knobs =
                        ChebOptions { max_probes: 3, max_steps: 2, ..cheb_fixed };
                    let a = chebyshev_logdet(op, &cheb_fixed).unwrap();
                    let b = chebyshev_logdet(op, &cheb_knobs).unwrap();
                    assert_estimates_bit_identical(
                        &format!("{name} cheb bs={bs} t={threads} {:?}", prec),
                        &a,
                        &b,
                    );
                    for est in [&a, &b] {
                        match &est.evidence {
                            gpsld::estimators::SpectralEvidence::Chebyshev {
                                resume, ..
                            } => assert!(resume.is_none(), "{name} fixed cheb resume"),
                            other => panic!("cheb evidence variant: {other:?}"),
                        }
                    }
                }
            }
        }
    }
}

/// Property (interval calibration): the 95% posterior interval contains
/// the exact log determinant at >= the advertised rate across randomized
/// kernels, sizes, and seeds — for SLQ (plain and preconditioned) and
/// Chebyshev. The interval is deliberately conservative (truncation terms
/// are upper bounds), so near-total coverage is expected; the gate at 90%
/// leaves room for a genuine 5% tail event without flaking.
#[test]
fn prop_interval_calibration_against_exact_logdet() {
    use gpsld::estimators::chebyshev::{chebyshev_logdet, ChebOptions};
    use gpsld::estimators::exact;
    use gpsld::estimators::slq::{slq_logdet, slq_logdet_pc, SlqOptions};
    use gpsld::solvers::{build_preconditioner, PrecondOptions, Preconditioner};
    let mut rng = Rng::new(2600);
    let mut hits = 0usize;
    let mut total = 0usize;
    for case in 0..10u64 {
        let n = 50 + rng.below(60);
        let sigma = 0.1 + 0.3 * rng.uniform();
        let pts: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
        let op = DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(rand_shape(&mut rng), 1, 0.4, 1.0)),
            sigma,
        );
        let truth = exact::exact_logdet(&op).unwrap();
        let slq = slq_logdet(
            &op,
            &SlqOptions {
                steps: 30,
                probes: 8,
                grads: false,
                seed: 3000 + case,
                ..Default::default()
            },
        )
        .unwrap();
        assert!(slq.interval.half_width().is_finite(), "case {case}: slq interval unbounded");
        hits += slq.interval.contains(truth) as usize;
        total += 1;
        let cheb = chebyshev_logdet(
            &op,
            &ChebOptions {
                degree: 70,
                probes: 8,
                grads: false,
                seed: 3000 + case,
                ..Default::default()
            },
        )
        .unwrap();
        hits += cheb.interval.contains(truth) as usize;
        total += 1;
        // Preconditioned SLQ: the exact log|P| offset shifts the interval
        // rigidly, so calibration must survive preconditioning.
        let pc = build_preconditioner(&op, PrecondOptions::rank(12)).unwrap();
        let pslq = slq_logdet_pc(
            &op,
            Some(&pc as &dyn Preconditioner),
            &SlqOptions {
                steps: 30,
                probes: 8,
                grads: false,
                seed: 4000 + case,
                ..Default::default()
            },
        )
        .unwrap();
        hits += pslq.interval.contains(truth) as usize;
        total += 1;
    }
    assert!(
        hits * 100 >= total * 90,
        "interval coverage {hits}/{total} below the 95% contract's 90% gate"
    );
}

/// Property (evidence retention invariance): the retained spectral
/// evidence — Lanczos tridiagonals / Chebyshev moment vectors — and the
/// interval synthesized from it are bit-identical across thread counts
/// and block sizes (evidence is per-probe data; fan-out must not touch
/// it).
#[test]
fn prop_evidence_invariant_across_threads_and_blocks() {
    use gpsld::estimators::chebyshev::{chebyshev_logdet, ChebOptions};
    use gpsld::estimators::slq::{slq_logdet, SlqOptions};
    use gpsld::estimators::SpectralEvidence;
    let mut rng = Rng::new(2700);
    let n = 70;
    let pts: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
    let op = DenseKernelOp::new(
        pts,
        Box::new(IsoKernel::new(Shape::Matern32, 1, 0.4, 1.0)),
        0.25,
    );
    let base_slq = slq_logdet(
        &op,
        &SlqOptions {
            steps: 18,
            probes: 8,
            seed: 13,
            block_size: 1,
            threads: 1,
            ..Default::default()
        },
    )
    .unwrap();
    let base_cheb = chebyshev_logdet(
        &op,
        &ChebOptions {
            degree: 30,
            probes: 8,
            seed: 13,
            lambda_bounds: Some((0.02, 40.0)),
            block_size: 1,
            threads: 1,
            ..Default::default()
        },
    )
    .unwrap();
    for bs in [2usize, 3, 8] {
        for threads in [1usize, 4] {
            let s = slq_logdet(
                &op,
                &SlqOptions {
                    steps: 18,
                    probes: 8,
                    seed: 13,
                    block_size: bs,
                    threads,
                    ..Default::default()
                },
            )
            .unwrap();
            match (&base_slq.evidence, &s.evidence) {
                (
                    SpectralEvidence::Lanczos { probes: pa, offset: oa, .. },
                    SpectralEvidence::Lanczos { probes: pb, offset: ob, .. },
                ) => {
                    assert_eq!(oa.to_bits(), ob.to_bits(), "slq offset bs={bs} t={threads}");
                    assert_eq!(pa.len(), pb.len(), "slq probe count bs={bs} t={threads}");
                    for (x, y) in pa.iter().zip(pb) {
                        assert_eq!(x.znorm2.to_bits(), y.znorm2.to_bits(), "slq znorm2");
                        assert_eq!(x.alphas.len(), y.alphas.len(), "slq alphas len");
                        for (a, c) in x.alphas.iter().zip(&y.alphas) {
                            assert_eq!(a.to_bits(), c.to_bits(), "slq alphas bs={bs} t={threads}");
                        }
                        for (a, c) in x.betas.iter().zip(&y.betas) {
                            assert_eq!(a.to_bits(), c.to_bits(), "slq betas bs={bs} t={threads}");
                        }
                    }
                }
                other => panic!("slq evidence variant changed: {other:?}"),
            }
            assert_eq!(
                base_slq.interval.lo.to_bits(),
                s.interval.lo.to_bits(),
                "slq interval lo bs={bs} t={threads}"
            );
            assert_eq!(
                base_slq.interval.hi.to_bits(),
                s.interval.hi.to_bits(),
                "slq interval hi bs={bs} t={threads}"
            );
            let c = chebyshev_logdet(
                &op,
                &ChebOptions {
                    degree: 30,
                    probes: 8,
                    seed: 13,
                    lambda_bounds: Some((0.02, 40.0)),
                    block_size: bs,
                    threads,
                    ..Default::default()
                },
            )
            .unwrap();
            match (&base_cheb.evidence, &c.evidence) {
                (
                    SpectralEvidence::Chebyshev { moments: ma, coeffs: ca, bracket: ba, .. },
                    SpectralEvidence::Chebyshev { moments: mb, coeffs: cb, bracket: bb, .. },
                ) => {
                    assert_eq!(ba.0.to_bits(), bb.0.to_bits(), "cheb bracket lo");
                    assert_eq!(ba.1.to_bits(), bb.1.to_bits(), "cheb bracket hi");
                    assert_eq!(ca.len(), cb.len(), "cheb coeff len");
                    for (a, c2) in ca.iter().zip(cb) {
                        assert_eq!(a.to_bits(), c2.to_bits(), "cheb coeffs");
                    }
                    assert_eq!(ma.len(), mb.len(), "cheb moment count bs={bs} t={threads}");
                    for (x, y) in ma.iter().zip(mb) {
                        assert_eq!(x.len(), y.len(), "cheb moment len");
                        for (a, c2) in x.iter().zip(y) {
                            assert_eq!(a.to_bits(), c2.to_bits(), "cheb moments bs={bs} t={threads}");
                        }
                    }
                }
                other => panic!("cheb evidence variant changed: {other:?}"),
            }
            assert_eq!(
                base_cheb.interval.lo.to_bits(),
                c.interval.lo.to_bits(),
                "cheb interval lo bs={bs} t={threads}"
            );
            assert_eq!(
                base_cheb.interval.hi.to_bits(),
                c.interval.hi.to_bits(),
                "cheb interval hi bs={bs} t={threads}"
            );
        }
    }
}

/// Property: derivative MVMs match finite differences for random SKI
/// configurations (routing/batching/state invariance of the operator).
#[test]
fn prop_ski_grad_fd_random_configs() {
    let mut rng = Rng::new(700);
    for case in 0..6 {
        let n = 20 + rng.below(20);
        let d = 1 + rng.below(2);
        let pts: Vec<Vec<f64>> =
            (0..n).map(|_| (0..d).map(|_| rng.uniform()).collect()).collect();
        let ms: Vec<usize> = (0..d).map(|_| 8 + rng.below(8)).collect();
        let grid = Grid::covering(&pts, &ms, 0.1);
        let shape = rand_shape(&mut rng);
        let diag = rng.below(2) == 0;
        let mut ski = SkiOp::new(
            &pts,
            grid,
            SeparableKernel::iso(shape, d, 0.3 + 0.3 * rng.uniform(), 1.0),
            0.2,
            InterpOrder::Cubic,
            diag,
        );
        let x: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let h0 = ski.hypers();
        let eps = 1e-6;
        for i in 0..ski.num_hypers() {
            let mut y = vec![0.0; n];
            ski.apply_grad(i, &x, &mut y);
            let mut hp = h0.clone();
            hp[i] += eps;
            ski.set_hypers(&hp);
            let up = ski.apply_vec(&x);
            hp[i] -= 2.0 * eps;
            ski.set_hypers(&hp);
            let dn = ski.apply_vec(&x);
            ski.set_hypers(&h0);
            for p in 0..n {
                let fd = (up[p] - dn[p]) / (2.0 * eps);
                assert!(
                    (y[p] - fd).abs() < 2e-4 * (1.0 + fd.abs()),
                    "case {case} hyper {i} entry {p}: {} vs {}",
                    y[p],
                    fd
                );
            }
        }
    }
}

/// Property (work-stealing scheduler): `cg_block` / `pcg_block` results
/// are bit-identical — solutions, per-column `CgInfo`, `mvms`,
/// `block_applies` — to the serial (static, in-order) engine for every
/// thread count in {1, 2, 8}, block size in {1, 3, 8}, cold and warm,
/// on a problem built for *maximally ragged* group convergence: a third
/// of the RHS columns are zero (their groups deflate at iteration 0 and
/// the worker immediately steals the next group) while the rest take the
/// full CG iteration count. Each multi-threaded configuration runs
/// several times so different steal interleavings are sampled; every run
/// must be bitwise identical, proving the steal order is unobservable.
#[test]
fn prop_work_stealing_bit_identical_across_steal_orders() {
    use gpsld::solvers::{
        build_preconditioner, pcg_block, CgOptions, PrecondOptions, Preconditioner,
    };
    let mut rng = Rng::new(3100);
    let n = 32;
    let k = 9;
    let pts: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform_in(0.0, 2.0)]).collect();
    let op = DenseKernelOp::new(
        pts,
        Box::new(IsoKernel::new(Shape::Rbf, 1, 0.6, 1.0)),
        0.05, // small noise: non-trivial iteration counts for hard columns
    );
    // Ragged RHS: columns j % 3 == 0 are zero (instant convergence, the
    // stealing worker moves on immediately); the rest are random.
    let b = Mat::from_fn(n, k, |i, j| {
        if j % 3 == 0 {
            0.0
        } else {
            ((i * 31 + j * 7) as f64 * 0.7311).sin()
        }
    });
    let x0 = Mat::from_fn(n, k, |_, _| 0.3 * rng.gaussian());
    let pc = build_preconditioner(&op, PrecondOptions::rank(8)).unwrap();
    for pc in [None, Some(&pc as &dyn Preconditioner)] {
        for warm in [None, Some(&x0)] {
            for bs in [1usize, 3, 8] {
                let serial = CgOptions {
                    tol: 1e-10,
                    max_iters: 400,
                    block_size: bs,
                    threads: 1,
                    ..Default::default()
                };
                let (xref, iref) = pcg_block(&op, &b, warm, pc, &serial);
                // The zero columns really do converge instantly — the
                // raggedness this property depends on is present.
                if warm.is_none() {
                    assert_eq!(iref.cols[0].iters, 0, "bs={bs}: zero column not instant");
                    assert!(
                        iref.cols[1].iters > 4,
                        "bs={bs}: hard column converged too fast for raggedness"
                    );
                }
                for threads in [2usize, 8] {
                    for round in 0..4 {
                        let opts = CgOptions { threads, ..serial };
                        let (xt, it) = pcg_block(&op, &b, warm, pc, &opts);
                        let tag = format!(
                            "pc={} warm={} bs={bs} threads={threads} round={round}",
                            pc.is_some(),
                            warm.is_some()
                        );
                        for (a, c) in xref.data.iter().zip(&xt.data) {
                            assert_eq!(a.to_bits(), c.to_bits(), "{tag}: {a} vs {c}");
                        }
                        assert_eq!(iref.mvms, it.mvms, "{tag} mvms");
                        assert_eq!(iref.block_applies, it.block_applies, "{tag} applies");
                        for (j, (a, c)) in iref.cols.iter().zip(&it.cols).enumerate() {
                            assert_eq!(a.iters, c.iters, "{tag} col {j} iters");
                            assert_eq!(a.converged, c.converged, "{tag} col {j} converged");
                            assert_eq!(a.mvms, c.mvms, "{tag} col {j} mvms");
                            assert_eq!(
                                a.residual.to_bits(),
                                c.residual.to_bits(),
                                "{tag} col {j} residual"
                            );
                        }
                    }
                }
            }
        }
    }
}

/// Property (request coalescing): fusing N pending predictive-variance
/// requests into one dispatched block solve answers every request
/// bitwise identically to N solo dispatches — across request counts,
/// preconditioned and not, with mean requests mixed into the batch — and
/// the fused path reports strictly fewer solves AND strictly fewer
/// block applies at equal convergence.
#[test]
fn prop_coalesced_dispatch_bitwise_matches_solo() {
    use gpsld::coordinator::service::{
        dispatch, Metrics, ModelRegistry, RequestKind, RequestQueue,
    };
    use gpsld::gp::GpRegression;
    use gpsld::solvers::{CgOptions, PrecondOptions};

    let make_model = |seed: u64, rank: usize| {
        let mut rng = Rng::new(seed);
        let n = 56;
        let pts: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform_in(0.0, 4.0)]).collect();
        let y: Vec<f64> =
            pts.iter().map(|p| (1.1 * p[0]).sin() + 0.1 * rng.gaussian()).collect();
        let op = DenseKernelOp::new(
            pts,
            Box::new(IsoKernel::new(Shape::Rbf, 1, 0.5, 1.0)),
            0.05,
        );
        let mut gp = GpRegression::new(op, y);
        gp.cg = CgOptions {
            tol: 1e-10,
            max_iters: 400,
            block_size: 16,
            threads: 1,
            precond: PrecondOptions::rank(rank),
            ..gp.cg
        };
        gp
    };

    let mut rng = Rng::new(3200);
    for case in 0..6 {
        let rank = if case % 2 == 0 { 0 } else { 8 };
        let n_var = 2 + rng.below(9);
        let n_mean = rng.below(4);
        let var_xs: Vec<Vec<f64>> =
            (0..n_var).map(|_| vec![rng.uniform_in(0.0, 4.0)]).collect();
        let mean_xs: Vec<Vec<f64>> =
            (0..n_mean).map(|_| vec![rng.uniform_in(0.0, 4.0)]).collect();

        // Coalesced: everything pending in one drain.
        let mut reg = ModelRegistry::new();
        let id = reg.insert(make_model(40 + case as u64, rank));
        let queue = RequestQueue::bounded(64);
        let metrics = Metrics::default();
        for x in &mean_xs {
            queue.submit(id, RequestKind::Mean, x.clone()).unwrap();
        }
        for x in &var_xs {
            queue.submit(id, RequestKind::Var, x.clone()).unwrap();
        }
        let fused = dispatch(&mut reg, &queue, &metrics);
        let (fused_solves, fused_applies, fused_cols, _) = metrics.serving_snapshot();
        assert_eq!(fused_cols, n_var, "case {case}");
        assert_eq!(fused_solves, 1, "case {case}");

        // Solo: identical model, one dispatch per request.
        let mut reg2 = ModelRegistry::new();
        let id2 = reg2.insert(make_model(40 + case as u64, rank));
        let solo_metrics = Metrics::default();
        let mut solo = Vec::new();
        for x in &mean_xs {
            let q = RequestQueue::bounded(8);
            q.submit(id2, RequestKind::Mean, x.clone()).unwrap();
            solo.extend(dispatch(&mut reg2, &q, &solo_metrics));
        }
        for x in &var_xs {
            let q = RequestQueue::bounded(8);
            q.submit(id2, RequestKind::Var, x.clone()).unwrap();
            solo.extend(dispatch(&mut reg2, &q, &solo_metrics));
        }
        let (solo_solves, solo_applies, _, _) = solo_metrics.serving_snapshot();

        assert_eq!(fused.len(), solo.len(), "case {case}");
        for (i, (f, s)) in fused.iter().zip(&solo).enumerate() {
            assert_eq!(f.kind, s.kind, "case {case} req {i}");
            assert_eq!(
                f.value.to_bits(),
                s.value.to_bits(),
                "case {case} req {i} ({:?}): {} vs {}",
                f.kind,
                f.value,
                s.value
            );
            assert_eq!(f.converged, s.converged, "case {case} req {i}");
            assert!(f.converged, "case {case} req {i}: must converge");
        }
        assert!(
            fused_solves < solo_solves,
            "case {case}: solves {fused_solves} !< {solo_solves}"
        );
        assert!(
            fused_applies < solo_applies,
            "case {case}: applies {fused_applies} !< {solo_applies}"
        );
    }
}

/// Property (resumable sessions): extending a retained Lanczos session in
/// stages is bitwise identical — tridiagonals, norms, e1 solves, MVM
/// accounting — to a from-scratch run at the final step count, for every
/// operator type (including the preconditioned split operator), block
/// widths {1, 3, 8}, and both MVM precisions. Chebyshev sessions carry
/// the same invariant on their raw moments and weighted quadratures.
#[test]
fn prop_session_resume_bitwise_across_ops() {
    use gpsld::estimators::chebyshev::{cheb_coeffs, ChebSession};
    use gpsld::estimators::lanczos::LanczosSession;
    use gpsld::estimators::probes::{ProbeKind, ProbeSet};

    for_each_precision_op(&mut |name, op| {
        let n = op.n();
        for cols in [1usize, 3, 8] {
            let z = ProbeSet::new(n, cols, ProbeKind::Rademacher, 900 + cols as u64).as_mat();
            for prec in [Precision::F64, Precision::F32F64] {
                let m = 11.min(n);
                let mut staged = LanczosSession::new(&z);
                staged.extend(op, 3.min(m), prec);
                staged.extend(op, 7.min(m), prec);
                staged.extend(op, m, prec);
                let mut scratch = LanczosSession::new(&z);
                scratch.extend(op, m, prec);
                let tag = format!("{name} cols={cols} {prec:?}");
                assert_eq!(staged.mvms(), scratch.mvms(), "{tag} mvms");
                assert_eq!(staged.block_applies(), scratch.block_applies(), "{tag} applies");
                for c in 0..cols {
                    let (sc, fc) = (staged.col(c), scratch.col(c));
                    assert_eq!(sc.znorm().to_bits(), fc.znorm().to_bits(), "{tag} znorm");
                    assert_eq!(sc.alphas().len(), fc.alphas().len(), "{tag} col {c} len");
                    for (a, b) in sc.alphas().iter().zip(fc.alphas()) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{tag} col {c} alpha");
                    }
                    for (a, b) in sc.betas().iter().zip(fc.betas()) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{tag} col {c} beta");
                    }
                    assert_eq!(sc.mvms(), fc.mvms(), "{tag} col {c} mvms");
                    for (a, b) in sc.solve_e1().iter().zip(&fc.solve_e1()) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{tag} col {c} e1 solve");
                    }
                }
            }
        }
    });

    // Chebyshev sessions need a KernelOp (coupled derivative recurrences);
    // dense + SKI cover both a dedicated-f32-panel op and a staged one.
    let mut rng = Rng::new(910);
    let n = 30;
    let pts: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
    let grid = Grid::covering(&pts, &[24], 0.1);
    let ski = SkiOp::new(
        &pts,
        grid,
        SeparableKernel::iso(Shape::Rbf, 1, 0.3, 1.0),
        0.2,
        InterpOrder::Cubic,
        false,
    );
    let dense = DenseKernelOp::new(
        pts.clone(),
        Box::new(IsoKernel::new(Shape::Matern32, 1, 0.4, 1.0)),
        0.25,
    );
    let bracket = (0.05, 40.0);
    let coeffs = cheb_coeffs(|t| (2.5 + t).ln(), 14);
    for (name, op) in [("dense", &dense as &dyn KernelOp), ("ski", &ski)] {
        for cols in [1usize, 3] {
            let z = ProbeSet::new(n, cols, ProbeKind::Rademacher, 920).as_mat();
            for prec in [Precision::F64, Precision::F32F64] {
                let mut staged = ChebSession::new(op, z.clone(), bracket, true, prec);
                staged.extend(op, 5);
                staged.extend(op, 14);
                let mut scratch = ChebSession::new(op, z.clone(), bracket, true, prec);
                scratch.extend(op, 14);
                let tag = format!("{name} cheb cols={cols} {prec:?}");
                assert_eq!(staged.mvms(), scratch.mvms(), "{tag} mvms");
                for (ms, mf) in staged.moments().iter().zip(scratch.moments()) {
                    for (a, b) in ms.iter().zip(mf) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{tag} moment");
                    }
                }
                for (a, b) in staged.quads(&coeffs).iter().zip(&scratch.quads(&coeffs)) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{tag} quad");
                }
                for (gs, gf) in
                    staged.grad_terms(&coeffs).iter().zip(&scratch.grad_terms(&coeffs))
                {
                    for (a, b) in gs.iter().zip(gf) {
                        assert_eq!(a.to_bits(), b.to_bits(), "{tag} grad term");
                    }
                }
            }
        }
    }
}

/// Compare the two-axis adaptive estimate against a fixed from-scratch
/// run at its final `(probes_used, steps_used)` budget — everything must
/// match bitwise except `block_applies`, whose amortization depends on
/// the adaptive chunk partition.
fn assert_adaptive_pins_to_fixed(
    name: &str,
    adaptive: &gpsld::estimators::LogdetEstimate,
    fixed: &gpsld::estimators::LogdetEstimate,
) {
    assert_eq!(adaptive.value.to_bits(), fixed.value.to_bits(), "{name} value");
    assert_eq!(adaptive.std_err.to_bits(), fixed.std_err.to_bits(), "{name} std_err");
    assert_eq!(adaptive.per_probe.len(), fixed.per_probe.len(), "{name} per_probe len");
    for (a, b) in adaptive.per_probe.iter().zip(&fixed.per_probe) {
        assert_eq!(a.to_bits(), b.to_bits(), "{name} per_probe");
    }
    assert_eq!(adaptive.grad.len(), fixed.grad.len(), "{name} grad len");
    for (a, b) in adaptive.grad.iter().zip(&fixed.grad) {
        assert_eq!(a.to_bits(), b.to_bits(), "{name} grad");
    }
    assert_eq!(adaptive.mvms, fixed.mvms, "{name} mvms");
    assert_eq!(adaptive.probes_used, fixed.probes_used, "{name} probes_used");
    assert_eq!(adaptive.steps_used, fixed.steps_used, "{name} steps_used");
    assert_eq!(
        adaptive.interval.lo.to_bits(),
        fixed.interval.lo.to_bits(),
        "{name} interval lo"
    );
    assert_eq!(
        adaptive.interval.hi.to_bits(),
        fixed.interval.hi.to_bits(),
        "{name} interval hi"
    );
}

/// Property (two-axis master pin): whatever `(probes_used, steps_used)`
/// the two-axis adaptive driver lands on, a fixed-budget from-scratch run
/// at exactly that budget reproduces the estimate bitwise — for dense and
/// SKI operators, block sizes {1, 3, 8}, threads {1, 4}, both precisions,
/// both estimators, and the preconditioned SLQ split. Growing budgets by
/// extending retained sessions must be indistinguishable from having
/// known the final budget all along.
#[test]
fn prop_two_axis_adaptive_pins_to_fixed_budget() {
    use gpsld::estimators::chebyshev::{chebyshev_logdet, ChebOptions};
    use gpsld::estimators::slq::{slq_logdet, slq_logdet_pc, SlqOptions};
    use gpsld::solvers::{build_preconditioner, PrecondOptions, Preconditioner};
    let mut rng = Rng::new(2800);
    let n = 60;
    let pts: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
    let grid = Grid::covering(&pts, &[32], 0.1);
    let ski = SkiOp::new(
        &pts,
        grid,
        SeparableKernel::iso(Shape::Rbf, 1, 0.3, 1.0),
        0.2,
        InterpOrder::Cubic,
        false,
    );
    let dense = DenseKernelOp::new(
        pts.clone(),
        Box::new(IsoKernel::new(Shape::Rbf, 1, 0.3, 1.0)),
        0.2,
    );
    for (name, op) in [("dense", &dense as &dyn KernelOp), ("ski", &ski)] {
        for bs in [1usize, 3, 8] {
            for threads in [1usize, 4] {
                for prec in [Precision::F64, Precision::F32F64] {
                    let adaptive_opts = SlqOptions {
                        steps: 6,
                        probes: 3,
                        seed: 17,
                        block_size: bs,
                        threads,
                        precision: prec,
                        target_tol: Some(1e-9), // unreachable: exhausts both axes
                        max_probes: 7,
                        max_steps: 0,
                        ..Default::default()
                    };
                    let adaptive = slq_logdet(op, &adaptive_opts).unwrap();
                    let fixed = slq_logdet(
                        op,
                        &SlqOptions {
                            steps: adaptive.steps_used,
                            probes: adaptive.probes_used,
                            target_tol: None,
                            ..adaptive_opts
                        },
                    )
                    .unwrap();
                    assert_adaptive_pins_to_fixed(
                        &format!("{name} slq bs={bs} t={threads} {prec:?}"),
                        &adaptive,
                        &fixed,
                    );
                    let cheb_opts = ChebOptions {
                        degree: 6,
                        probes: 3,
                        seed: 17,
                        lambda_bounds: Some((0.02, 40.0)),
                        block_size: bs,
                        threads,
                        precision: prec,
                        target_tol: Some(1e-9),
                        max_probes: 7,
                        max_steps: 0,
                        ..Default::default()
                    };
                    let cadaptive = chebyshev_logdet(op, &cheb_opts).unwrap();
                    let cfixed = chebyshev_logdet(
                        op,
                        &ChebOptions {
                            degree: cadaptive.steps_used,
                            probes: cadaptive.probes_used,
                            target_tol: None,
                            ..cheb_opts
                        },
                    )
                    .unwrap();
                    assert_adaptive_pins_to_fixed(
                        &format!("{name} cheb bs={bs} t={threads} {prec:?}"),
                        &cadaptive,
                        &cfixed,
                    );
                }
            }
        }
    }

    // Preconditioned split: sessions run on the flattened operator, the
    // exact log|P| offset rides through both axes unchanged.
    let pc = build_preconditioner(&dense, PrecondOptions::rank(8)).unwrap();
    for bs in [1usize, 3] {
        let adaptive_opts = SlqOptions {
            steps: 6,
            probes: 3,
            seed: 19,
            block_size: bs,
            grads: true,
            target_tol: Some(1e-9),
            max_probes: 7,
            max_steps: 0,
            ..Default::default()
        };
        let adaptive =
            slq_logdet_pc(&dense, Some(&pc as &dyn Preconditioner), &adaptive_opts).unwrap();
        let fixed = slq_logdet_pc(
            &dense,
            Some(&pc as &dyn Preconditioner),
            &SlqOptions {
                steps: adaptive.steps_used,
                probes: adaptive.probes_used,
                target_tol: None,
                ..adaptive_opts
            },
        )
        .unwrap();
        assert_adaptive_pins_to_fixed(&format!("pc slq bs={bs}"), &adaptive, &fixed);
    }
}

/// Bitwise equality of every observable field of two [`LogdetEstimate`]s
/// (values, grads, per-probe evidence, interval, accounting). The
/// evidence enum is compared via its Debug rendering — Rust float
/// formatting round-trips uniquely, so two renders agree iff the floats
/// do (the numerics here never produce NaN payload differences).
fn assert_estimates_bitwise(tag: &str, a: &gpsld::estimators::LogdetEstimate, b: &gpsld::estimators::LogdetEstimate) {
    assert_eq!(a.value.to_bits(), b.value.to_bits(), "{tag} value");
    assert_eq!(a.std_err.to_bits(), b.std_err.to_bits(), "{tag} std_err");
    assert_eq!(a.grad.len(), b.grad.len(), "{tag} grad len");
    for (x, y) in a.grad.iter().zip(&b.grad) {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag} grad");
    }
    assert_eq!(a.per_probe.len(), b.per_probe.len(), "{tag} per_probe len");
    for (x, y) in a.per_probe.iter().zip(&b.per_probe) {
        assert_eq!(x.to_bits(), y.to_bits(), "{tag} per_probe");
    }
    assert_eq!(a.mvms, b.mvms, "{tag} mvms");
    assert_eq!(a.block_applies, b.block_applies, "{tag} block_applies");
    assert_eq!(a.probes_used, b.probes_used, "{tag} probes_used");
    assert_eq!(a.steps_used, b.steps_used, "{tag} steps_used");
    assert_eq!(a.interval.lo.to_bits(), b.interval.lo.to_bits(), "{tag} interval lo");
    assert_eq!(a.interval.hi.to_bits(), b.interval.hi.to_bits(), "{tag} interval hi");
    assert_eq!(
        format!("{:?}", a.evidence),
        format!("{:?}", b.evidence),
        "{tag} evidence"
    );
}

/// Property (tracing inert): enabling the `util::obs` span/counter
/// registry is observation-only. Solves and estimates run with tracing on
/// are bitwise identical to the disabled default — solutions, per-column
/// statistics, estimator values, grads, per-probe evidence, intervals,
/// and the mvms/block_applies accounting — for every operator type,
/// block sizes {1, 8}, threads {1, 8}, and both precisions. This is the
/// license for the CLI to flip `--trace` on without a bit of fear (and
/// the audit asserts inside the traced runs double as the release-build
/// check that counted applies equal the accounting).
#[test]
fn prop_tracing_enabled_bitwise_inert() {
    use gpsld::estimators::chebyshev::{chebyshev_logdet, ChebOptions};
    use gpsld::estimators::slq::{slq_logdet, slq_logdet_pc, SlqOptions};
    use gpsld::solvers::{
        build_preconditioner, cg_block, pcg_block, CgOptions, Preconditioner, PrecondOptions,
    };
    use gpsld::util::obs;

    // Serialize against any other test toggling the global registry; the
    // with_enabled guards below restore the prior state on every path.
    let _guard = obs::test_lock().lock().unwrap_or_else(|e| e.into_inner());

    // Solves: every operator type x blocks {1, 8} x threads {1, 8} x
    // both precisions.
    for_each_precision_op(&mut |name, op| {
        let n = op.n();
        let mut rng = Rng::new(3100);
        let b = Mat::from_fn(n, 4, |_, _| rng.gaussian());
        for blk in [1usize, 8] {
            for threads in [1usize, 8] {
                for prec in [Precision::F64, Precision::F32F64] {
                    let opts = CgOptions {
                        tol: 1e-9,
                        max_iters: 300,
                        block_size: blk,
                        threads,
                        precision: prec,
                        ..Default::default()
                    };
                    let (x_off, i_off) =
                        obs::with_enabled(false, || cg_block(op, &b, None, &opts));
                    let (x_on, i_on) =
                        obs::with_enabled(true, || cg_block(op, &b, None, &opts));
                    let tag = format!("{name} cg blk={blk} t={threads} {prec:?}");
                    for (p, q) in x_off.data.iter().zip(&x_on.data) {
                        assert_eq!(p.to_bits(), q.to_bits(), "{tag} solution");
                    }
                    assert_eq!(i_off.mvms, i_on.mvms, "{tag} mvms");
                    assert_eq!(i_off.block_applies, i_on.block_applies, "{tag} applies");
                    assert_eq!(i_off.cols.len(), i_on.cols.len(), "{tag} cols");
                    for (c, d) in i_off.cols.iter().zip(&i_on.cols) {
                        assert_eq!(c.iters, d.iters, "{tag} iters");
                        assert_eq!(c.mvms, d.mvms, "{tag} col mvms");
                        assert_eq!(c.converged, d.converged, "{tag} converged");
                        assert_eq!(c.residual.to_bits(), d.residual.to_bits(), "{tag} residual");
                    }
                }
            }
        }
    });

    // Preconditioned solves + estimators on a dense kernel (the pcg path,
    // the preconditioned-SLQ split, and the Chebyshev auto-bracket whose
    // helper MVMs are counter-suppressed but must stay numerically inert
    // too).
    let mut rng = Rng::new(3200);
    let n = 40;
    let pts: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform_in(0.0, 3.0)]).collect();
    let dense = DenseKernelOp::new(
        pts.clone(),
        Box::new(IsoKernel::new(Shape::Matern32, 1, 0.4, 1.0)),
        0.2,
    );
    let grid = Grid::covering(&pts, &[32], 0.1);
    let ski = SkiOp::new(
        &pts,
        grid,
        SeparableKernel::iso(Shape::Rbf, 1, 0.3, 1.0),
        0.2,
        InterpOrder::Cubic,
        false,
    );
    let pc = build_preconditioner(&dense, PrecondOptions::rank(6)).unwrap();
    let b = Mat::from_fn(n, 4, |_, _| rng.gaussian());
    for blk in [1usize, 8] {
        for threads in [1usize, 8] {
            for prec in [Precision::F64, Precision::F32F64] {
                let opts = CgOptions {
                    tol: 1e-9,
                    max_iters: 300,
                    block_size: blk,
                    threads,
                    precision: prec,
                    ..Default::default()
                };
                let run = || {
                    pcg_block(&dense, &b, None, Some(&pc as &dyn Preconditioner), &opts)
                };
                let (x_off, i_off) = obs::with_enabled(false, run);
                let (x_on, i_on) = obs::with_enabled(true, run);
                let tag = format!("pcg blk={blk} t={threads} {prec:?}");
                for (p, q) in x_off.data.iter().zip(&x_on.data) {
                    assert_eq!(p.to_bits(), q.to_bits(), "{tag} solution");
                }
                assert_eq!(i_off.mvms, i_on.mvms, "{tag} mvms");
                assert_eq!(i_off.block_applies, i_on.block_applies, "{tag} applies");
            }
        }
    }
    for (name, op) in [("dense", &dense as &dyn KernelOp), ("ski", &ski)] {
        for blk in [1usize, 8] {
            for threads in [1usize, 8] {
                for prec in [Precision::F64, Precision::F32F64] {
                    let slq_opts = SlqOptions {
                        steps: 10,
                        probes: 4,
                        seed: 31,
                        grads: true,
                        block_size: blk,
                        threads,
                        precision: prec,
                        ..Default::default()
                    };
                    let s_off =
                        obs::with_enabled(false, || slq_logdet(op, &slq_opts).unwrap());
                    let s_on =
                        obs::with_enabled(true, || slq_logdet(op, &slq_opts).unwrap());
                    assert_estimates_bitwise(
                        &format!("{name} slq blk={blk} t={threads} {prec:?}"),
                        &s_off,
                        &s_on,
                    );
                    // lambda_bounds: None exercises the auto-bracket.
                    let cheb_opts = ChebOptions {
                        degree: 16,
                        probes: 4,
                        seed: 31,
                        grads: true,
                        lambda_bounds: None,
                        block_size: blk,
                        threads,
                        precision: prec,
                        ..Default::default()
                    };
                    let c_off =
                        obs::with_enabled(false, || chebyshev_logdet(op, &cheb_opts).unwrap());
                    let c_on =
                        obs::with_enabled(true, || chebyshev_logdet(op, &cheb_opts).unwrap());
                    assert_estimates_bitwise(
                        &format!("{name} cheb blk={blk} t={threads} {prec:?}"),
                        &c_off,
                        &c_on,
                    );
                }
            }
        }
    }
    // Preconditioned SLQ (the split estimator) once per block width.
    for blk in [1usize, 8] {
        let opts = SlqOptions {
            steps: 10,
            probes: 4,
            seed: 37,
            grads: true,
            block_size: blk,
            ..Default::default()
        };
        let s_off = obs::with_enabled(false, || {
            slq_logdet_pc(&dense, Some(&pc as &dyn Preconditioner), &opts).unwrap()
        });
        let s_on = obs::with_enabled(true, || {
            slq_logdet_pc(&dense, Some(&pc as &dyn Preconditioner), &opts).unwrap()
        });
        assert_estimates_bitwise(&format!("pc slq blk={blk}"), &s_off, &s_on);
    }
}
