//! Integration tests for the PJRT runtime: load the AOT artifacts produced
//! by `make artifacts` and check their numerics against the native rust
//! operators. Skipped (with a message) when artifacts/ is absent.

use gpsld::kernels::{IsoKernel, Shape};
use gpsld::linalg::dense::Mat;
use gpsld::operators::{DenseKernelOp, KernelOp, LinOp};
use gpsld::runtime::ops::{HybridKernelOp, PjrtLanczos, PjrtMvmOp};
use gpsld::runtime::PjrtRuntime;
use gpsld::util::rng::Rng;
use std::sync::Arc;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.tsv").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn runtime() -> Option<Arc<PjrtRuntime>> {
    artifacts_dir().map(|d| Arc::new(PjrtRuntime::new(d).expect("pjrt runtime")))
}

fn rand_points(n: usize, d: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| (0..d).map(|_| rng.gaussian()).collect()).collect()
}

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(rt) = runtime() else { return };
    let names = rt.names();
    assert!(names.iter().any(|n| n.starts_with("mvm_rbf_n512")), "{names:?}");
    assert!(names.iter().any(|n| n.starts_with("lanczos_rbf")), "{names:?}");
}

#[test]
fn pjrt_mvm_matches_native_dense() {
    let Some(rt) = runtime() else { return };
    let pts = rand_points(512, 2, 1);
    let (ell, sf, sigma) = (0.7, 1.2, 0.3);
    let op = PjrtMvmOp::new(rt, "mvm_rbf_n512_d2_b8", &pts, ell, sf, sigma).unwrap();
    let native = DenseKernelOp::new(
        pts.clone(),
        Box::new(IsoKernel::new(Shape::Rbf, 2, ell, sf)),
        sigma,
    );
    let mut rng = Rng::new(2);
    let x: Vec<f64> = (0..512).map(|_| rng.gaussian()).collect();
    let got = op.apply_vec(&x);
    let want = native.apply_vec(&x);
    let scale = want.iter().map(|v| v.abs()).fold(1.0, f64::max);
    for i in 0..512 {
        assert!(
            (got[i] - want[i]).abs() / scale < 5e-4,
            "i={i}: {} vs {} (f32 artifact)",
            got[i],
            want[i]
        );
    }
}

#[test]
fn pjrt_mvm_batch_matches_columns() {
    let Some(rt) = runtime() else { return };
    let pts = rand_points(512, 2, 3);
    let op = PjrtMvmOp::new(rt, "mvm_rbf_n512_d2_b8", &pts, 0.5, 1.0, 0.2).unwrap();
    let mut rng = Rng::new(4);
    let x = Mat::from_fn(512, 11, |_, _| rng.gaussian());
    let batched = op.apply_mat(&x);
    for j in 0..11 {
        let col = op.apply_vec(&x.col(j));
        for i in 0..512 {
            assert!((batched[(i, j)] - col[i]).abs() < 1e-9);
        }
    }
}

#[test]
fn hybrid_op_runs_slq_against_artifact() {
    let Some(rt) = runtime() else { return };
    let pts = rand_points(512, 2, 5);
    let hybrid =
        HybridKernelOp::new(rt, "mvm_rbf_n512_d2_b8", pts.clone(), 0.6, 1.0, 0.3).unwrap();
    let est = gpsld::estimators::slq::slq_logdet(
        &hybrid,
        &gpsld::estimators::slq::SlqOptions {
            steps: 25,
            probes: 6,
            seed: 7,
            ..Default::default()
        },
    )
    .unwrap();
    let exact = gpsld::estimators::exact::exact_logdet(&hybrid.native).unwrap();
    assert!(
        (est.value - exact).abs() < 0.05 * exact.abs().max(1.0) + 4.0 * est.std_err,
        "{} vs {exact}",
        est.value
    );
    // Gradients flow through the native side.
    assert_eq!(est.grad.len(), hybrid.num_hypers());
    assert!(est.grad.iter().all(|g| g.is_finite()));
}

#[test]
fn pjrt_lanczos_graph_estimates_logdet() {
    let Some(rt) = runtime() else { return };
    let pts = rand_points(2048, 2, 6);
    let lz = PjrtLanczos::new(rt, "lanczos_rbf_n2048_d2_p8_m30", &pts).unwrap();
    assert_eq!((lz.n, lz.p, lz.m), (2048, 8, 30));
    let mut rng = Rng::new(8);
    let z = Mat::from_fn(2048, 8, |_, _| rng.rademacher());
    let (ell, sf, sigma) = (0.5, 1.0, 0.4);
    let (est, se) = lz.slq_logdet(&z, ell, sf, sigma).unwrap();
    // Native SLQ reference on the same problem.
    let native = DenseKernelOp::new(
        pts,
        Box::new(IsoKernel::new(Shape::Rbf, 2, ell, sf)),
        sigma,
    );
    let nat = gpsld::estimators::slq::slq_logdet(
        &native,
        &gpsld::estimators::slq::SlqOptions {
            steps: 30,
            probes: 8,
            grads: false,
            seed: 9,
            ..Default::default()
        },
    )
    .unwrap();
    assert!(
        (est - nat.value).abs() < 0.03 * nat.value.abs().max(1.0) + 4.0 * (se + nat.std_err),
        "pjrt {est} (se {se}) vs native {} (se {})",
        nat.value,
        nat.std_err
    );
}

#[test]
fn pjrt_lanczos_g_solves_system() {
    let Some(rt) = runtime() else { return };
    let pts = rand_points(2048, 2, 10);
    let lz = PjrtLanczos::new(rt, "lanczos_rbf_n2048_d2_p8_m30", &pts).unwrap();
    let mut rng = Rng::new(11);
    let z = Mat::from_fn(2048, 8, |_, _| rng.rademacher());
    let (ell, sf, sigma) = (0.4, 1.0, 0.5);
    let out = lz.run(&z, ell, sf, sigma).unwrap();
    // Check K g ≈ z on the first probe column via the native operator.
    let native = DenseKernelOp::new(
        pts,
        Box::new(IsoKernel::new(Shape::Rbf, 2, ell, sf)),
        sigma,
    );
    let g0 = out.g.col(0);
    let kg = native.apply_vec(&g0);
    let z0 = z.col(0);
    let num: f64 = kg.iter().zip(&z0).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
    let den: f64 = z0.iter().map(|v| v * v).sum::<f64>().sqrt();
    assert!(num / den < 0.05, "relative residual {}", num / den);
}
