//! `cargo bench` target regenerating Supp. Fig. 6: diagonal correction.
//! Runs the coordinator driver at Small scale; `gpsld exp fig6 --scale paper`
//! reproduces the full-size version.
use gpsld::coordinator::{cli, Scale};
use gpsld::util::bench::Bench;

fn main() {
    Bench::header("Supp. Fig. 6: diagonal correction");
    let mut b = Bench::one_shot();
    let mut out = None;
    b.run("fig6 (small scale, end-to-end)", || {
        out = cli::run_experiment("fig6", Scale::Small);
    });
    if let Some(res) = out {
        res.print("Supp. Fig. 6: diagonal correction — regenerated rows");
    }
}
