//! `cargo bench` target regenerating Supp. Fig. 7: surrogate level curves.
//! Runs the coordinator driver at Small scale; `gpsld exp fig7 --scale paper`
//! reproduces the full-size version.
use gpsld::coordinator::{cli, Scale};
use gpsld::util::bench::Bench;

fn main() {
    Bench::header("Supp. Fig. 7: surrogate level curves");
    let mut b = Bench::one_shot();
    let mut out = None;
    b.run("fig7 (small scale, end-to-end)", || {
        out = cli::run_experiment("fig7", Scale::Small);
    });
    if let Some(res) = out {
        res.print("Supp. Fig. 7: surrogate level curves — regenerated rows");
    }
}
