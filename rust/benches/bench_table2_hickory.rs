//! `cargo bench` target regenerating Table 2: Hickory LGCP hyper recovery.
//! Runs the coordinator driver at Small scale; `gpsld exp table2 --scale paper`
//! reproduces the full-size version.
use gpsld::coordinator::{cli, Scale};
use gpsld::util::bench::Bench;

fn main() {
    Bench::header("Table 2: Hickory LGCP hyper recovery");
    let mut b = Bench::one_shot();
    let mut out = None;
    b.run("table2 (small scale, end-to-end)", || {
        out = cli::run_experiment("table2", Scale::Small);
    });
    if let Some(res) = out {
        res.print("Table 2: Hickory LGCP hyper recovery — regenerated rows");
    }
}
