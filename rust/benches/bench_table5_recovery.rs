//! `cargo bench` target regenerating Supp. Table 5: hyperparameter recovery.
//! Runs the coordinator driver at Small scale; `gpsld exp table5 --scale paper`
//! reproduces the full-size version.
use gpsld::coordinator::{cli, Scale};
use gpsld::util::bench::Bench;

fn main() {
    Bench::header("Supp. Table 5: hyperparameter recovery");
    let mut b = Bench::one_shot();
    let mut out = None;
    b.run("table5 (small scale, end-to-end)", || {
        out = cli::run_experiment("table5", Scale::Small);
    });
    if let Some(res) = out {
        res.print("Supp. Table 5: hyperparameter recovery — regenerated rows");
    }
}
