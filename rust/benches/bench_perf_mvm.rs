//! §Perf micro/meso benchmarks: MVM throughput per operator structure
//! (dense native, PJRT/Pallas artifact, Toeplitz-SKI scaling in m),
//! Lanczos/Chebyshev estimator cost, and CG solves. These are the numbers
//! recorded before/after each optimization step in EXPERIMENTS.md §Perf.

use gpsld::coordinator::{cli, Scale};
use gpsld::data;
use gpsld::estimators::chebyshev::{chebyshev_logdet, ChebOptions};
use gpsld::estimators::slq::{slq_logdet, SlqOptions};
use gpsld::grid::{Grid, InterpOrder};
use gpsld::kernels::{SeparableKernel, Shape};
use gpsld::operators::{KernelOp, LinOp, SkiOp};
use gpsld::solvers::cg::cg;
use gpsld::util::bench::{black_box, Bench};
use gpsld::util::rng::Rng;

fn main() {
    let mut b = Bench::new(1.0);
    let mut rng = Rng::new(3);

    // --- SKI MVM scaling in m (paper: O(n + m log m)) ---
    Bench::header("SKI (Toeplitz) MVM, n = 8000");
    let d = data::sound(8000, 3, 40, 9);
    let mut skis = Vec::new();
    for m in [1000usize, 4000, 16000, 64000] {
        let grid = Grid::covering(&d.x_train, &[m], 0.05);
        let ski = SkiOp::new(
            &d.x_train,
            grid,
            SeparableKernel::iso(Shape::Rbf, 1, 0.004, 0.5),
            0.1,
            InterpOrder::Cubic,
            false,
        );
        let x: Vec<f64> = (0..d.n_train()).map(|_| rng.gaussian()).collect();
        let mut y = vec![0.0; d.n_train()];
        b.run(&format!("ski_mvm n=8000 m={m}"), || {
            ski.apply(&x, &mut y);
            black_box(y[0])
        });
        skis.push(ski);
    }

    // --- Estimators end-to-end on SKI m=4000 ---
    Bench::header("logdet estimators on SKI n=8000 m=4000 (3 hypers, grads)");
    let ski = &skis[1];
    b.run("slq 25x5 with grads", || {
        black_box(
            slq_logdet(
                ski,
                &SlqOptions { steps: 25, probes: 5, seed: 1, ..Default::default() },
            )
            .unwrap()
            .value,
        )
    });
    b.run("slq 25x5 value only", || {
        black_box(
            slq_logdet(
                ski,
                &SlqOptions { steps: 25, probes: 5, grads: false, seed: 1, ..Default::default() },
            )
            .unwrap()
            .value,
        )
    });
    b.run("chebyshev 50x5 with grads", || {
        black_box(
            chebyshev_logdet(
                ski,
                &ChebOptions { degree: 50, probes: 5, seed: 1, ..Default::default() },
            )
            .unwrap()
            .value,
        )
    });

    // --- CG solve (the alpha term) ---
    Bench::header("CG solve on SKI n=8000 m=4000");
    let rhs: Vec<f64> = (0..d.n_train()).map(|_| rng.gaussian()).collect();
    b.run("cg tol=1e-8", || {
        let (x, info) = cg(ski, &rhs, 1e-8, 500);
        black_box((x[0], info.iters))
    });

    // --- Dense + PJRT artifact paths (the L1/L2 hot path) ---
    if let Some(res) = cli::run_experiment("perf", Scale::Small) {
        res.print("perf experiment (dense native vs PJRT vs SKI)");
    }

    // --- SKI derivative MVMs (apply_grad hot path) ---
    Bench::header("SKI derivative MVMs");
    let x: Vec<f64> = (0..d.n_train()).map(|_| rng.gaussian()).collect();
    let mut y = vec![0.0; d.n_train()];
    for i in 0..ski.num_hypers() {
        b.run(&format!("apply_grad hyper {i}"), || {
            ski.apply_grad(i, &x, &mut y);
            black_box(y[0])
        });
    }
}
