//! §Perf micro/meso benchmarks: MVM throughput per operator structure
//! (dense native, PJRT/Pallas artifact, Toeplitz-SKI scaling in m),
//! blocked `apply_mat` block-size sweeps, Lanczos/Chebyshev estimator cost,
//! and CG solves. These are the numbers recorded before/after each
//! optimization step in EXPERIMENTS.md §Perf.
//!
//! Machine-readable mode (used by `scripts/bench_smoke.sh`):
//!
//! ```text
//! cargo bench --bench bench_perf_mvm -- --smoke \
//!     --json BENCH_mvm.json --json-cg BENCH_cg.json
//! ```
//!
//! runs the dense/Toeplitz/SKI block sweep at n in {1k, 4k}, b in
//! {1, 8, 32}, once per precision mode, and writes one JSON row per case:
//! `{op, n, b, precision, ns_per_apply, gbps}` where `precision` is the
//! MVM mode (`"f64"` baseline / `"f32f64"` mixed — f32 storage panels,
//! f64 accumulation), `ns_per_apply` is ns per probe-column and `gbps` is
//! *modeled* memory traffic (documented per operator below) — a
//! trajectory metric, not a hardware counter.
//!
//! `--json-cg` additionally runs the block-CG solve sweep and writes
//! `{op, n, rhs, block, threads, precision, ns_per_solve_col, mvms,
//! block_applies, converged}` per case: `ns_per_solve_col` is wall time
//! per right-hand-side column, `threads` is the RHS-group worker count (a
//! 1-vs-N sweep; solver results are bit-identical across thread counts,
//! so `mvms` / `block_applies` / `converged` only depend on the other
//! fields), `precision` selects the inner-iteration MVM mode (`f32f64`
//! solves still confirm convergence against the f64 true residual, so
//! `converged` means the same thing in both modes), `mvms` /
//! `block_applies` mirror `BlockCgInfo` (block-amortized applies are the
//! hardware-executed count and must be <= per-column MVMs), and
//! `converged` counts columns that hit the tolerance.
//!
//! `--json-precond` runs the pivoted-Cholesky preconditioning sweep
//! (rank × σ × (block, threads) on an ill-conditioned dense RBF kernel)
//! and writes `{op, n, sigma, rank, block, threads, cg_iters, converged,
//! lanczos_steps, ns_per_solve_col}` per case — rank 0 is the
//! unpreconditioned baseline, block 8 the single-group amortized
//! production configuration (its thread budget drives operator-internal
//! threading), block 2 the 4-group RHS fan-out, and threads 1 each
//! block's serial baseline, so the iteration-count and wall-clock
//! reductions are measured rather than asserted.
//!
//! `--json-conf` runs the confidence/adaptive-budget sweep (tolerance × σ
//! on the same ill-conditioned dense RBF kernel) and writes `{op, n,
//! sigma, tol, probes_used, steps_used, mvms, interval_width, calibrated,
//! ns_per_estimate}` per case — tol 0 is the fixed-budget baseline;
//! adaptive rows come from the two-axis driver, so on the small-σ cases
//! `steps_used` grows past the 10-step seed budget while the easy cases
//! stop on probes alone, and `mvms` (gated lower-is-better, like
//! `probes_used`) is the total cost the axis choice is about — the sweep
//! itself asserts in release builds that deepening beat the probes-only
//! driver (see `conf_sweep`). `calibrated` is 1 iff the 95% interval
//! contains the exact log determinant (a calibration regression fails
//! the gate loudly).
//!
//! `--json-service` runs the streaming-service request-replay sweep
//! (`requests` single-column predictive-variance requests coalesced into
//! one fused cold block solve per drain; the sweep itself asserts the
//! fused answers bitwise-equal the solo per-request baseline, and runs
//! every case at both solve precisions — `precision` is an identity
//! field, so the `f32f64` rows gate against their own history) and writes
//! `{model, n, requests, threads, precision, coalesced_cols, solves,
//! block_applies, converged, p50_ns, p99_ns}` per case — `solves` and
//! `block_applies` are the coalesced cost (gated lower-is-better: losing
//! the amortization fails loudly), `converged` counts converged responses
//! (higher-is-better: fewer applies from giving up must not read as a
//! win), and `p50_ns`/`p99_ns` are per-request latency quantiles
//! (timing-gated with the usual noise floor). The solo-baseline counters
//! are deliberately *not* in the row: they are asserted inside
//! `service_sweep`, and keeping them out of the JSON means future solver
//! improvements don't churn row identity.
//!
//! `--json-trace` runs a fixed traced workload (pivoted-Cholesky build +
//! SLQ logdet + preconditioned block solve on a dense RBF kernel) under
//! the `util::obs` span registry and writes one row per *layer* — the
//! flat by-span-name self-time rollup — `{layer, n, calls,
//! self_ns_per_run, self_share, mvms, block_applies}`: `self_ns_per_run`
//! is timing-class (gated with the usual ns floor), `calls` / `mvms` /
//! `block_applies` are exact counters (the workload is deterministic, so
//! a count change is a real cost change, not noise), and `self_share` is
//! informational (shares shuffle whenever any layer speeds up; gating
//! them would double-count the timing signal). One extra
//! `layer="tracing_overhead"` row times the SAME workload with tracing
//! enabled vs disabled and reports the difference per run (clamped at 0,
//! timing-floored) — the disabled-mode cost of the instrumentation is a
//! few relaxed atomic loads per site, and this row keeps it that way.

use std::time::Instant;

use gpsld::coordinator::figures::{
    conf_sweep, precond_sweep, service_sweep, ConfSweepRow, PrecondSweepRow, ServiceSweepRow,
    SWEEP_THREADS,
};
use gpsld::coordinator::{cli, Scale};
use gpsld::data;
use gpsld::estimators::chebyshev::{chebyshev_logdet, ChebOptions};
use gpsld::estimators::slq::{slq_logdet, SlqOptions};
use gpsld::grid::{Grid, InterpOrder};
use gpsld::kernels::{IsoKernel, SeparableKernel, Shape};
use gpsld::linalg::dense::Mat;
use gpsld::operators::{DenseKernelOp, KernelOp, LinOp, ShiftedOp, SkiOp, ToeplitzOp};
use gpsld::solvers::{cg, cg_block, CgOptions};
use gpsld::util::bench::{black_box, Bench};
use gpsld::util::rng::Rng;

/// One measured sweep case for the JSON report.
struct SweepRow {
    op: &'static str,
    n: usize,
    b: usize,
    /// MVM precision mode for this row (`"f64"` / `"f32f64"`) — an
    /// identity field in `bench_compare.py`, so the mixed rows are gated
    /// against their own history, never against the f64 baseline.
    precision: &'static str,
    ns_per_apply: f64,
    gbps: f64,
}

/// Warmup-then-budgeted-reps timing loop: run `f` once untimed, then
/// repeat until `cap` reps or (`min_reps` reps and `budget_s` elapsed).
fn time_adaptive(cap: usize, min_reps: usize, budget_s: f64, mut f: impl FnMut() -> f64) -> f64 {
    black_box(f()); // warmup
    let mut iters = 0usize;
    let start = Instant::now();
    let mut elapsed;
    loop {
        black_box(f());
        iters += 1;
        elapsed = start.elapsed().as_secs_f64();
        if iters >= cap || (iters >= min_reps && elapsed > budget_s) {
            break;
        }
    }
    elapsed / iters as f64
}

/// Time `f` (which applies one full block) and return seconds per call.
fn time_block(f: impl FnMut() -> f64) -> f64 {
    time_adaptive(20, 3, 0.3, f)
}

fn log2_usize(x: usize) -> usize {
    (usize::BITS - x.leading_zeros()) as usize - 1
}

/// Dense/Toeplitz/SKI block sweep at the given sizes, once per precision
/// mode (the `f32f64` rows time [`LinOp::apply_mat_prec`], f32-panel
/// caches warmed by the untimed warmup apply). Modeled bytes per block
/// apply — the mixed rows model the f32 storage panels where a path
/// actually has one:
/// * dense: one pass over K plus the block in/out — `8 n² + 16 n b`
///   (f64) / `4 n² + 16 n b` (mixed: K panel is f32, block stays f64);
/// * toeplitz: per column, 2 FFTs of length L touching `16 L` bytes per
///   stage plus one spectrum read — `16 b L (2 log2 L + 1)` in *both*
///   modes (mixed only stages in/out; the transform stays f64);
/// * ski: two CSR sweeps plus the grid-factor circulant —
///   `b (32 nnz + 16 L (2 log2 L + 1))` (f64) / `b (16 nnz + ...)`
///   (mixed: f32 values + u32 indices halve the sweep).
fn block_sweep(ns: &[usize], bs: &[usize]) -> Vec<SweepRow> {
    use gpsld::util::precision::Precision;
    const PRECISIONS: [Precision; 2] = [Precision::F64, Precision::F32F64];
    let mut rows = Vec::new();
    let mut rng = Rng::new(7);
    for &n in ns {
        // Dense kernel operator on 2-D points.
        let pts2: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.gaussian(), rng.gaussian()]).collect();
        let dense = DenseKernelOp::new(
            pts2,
            Box::new(IsoKernel::new(Shape::Rbf, 2, 0.5, 1.0)),
            0.3,
        );
        for &b in bs {
            let x = Mat::from_fn(n, b, |_, _| rng.gaussian());
            for prec in PRECISIONS {
                let secs = time_block(|| dense.apply_mat_prec(&x, prec).data[0]);
                let kbytes = match prec {
                    Precision::F64 => 8.0,
                    Precision::F32F64 => 4.0,
                };
                let bytes = kbytes * (n as f64 * n as f64) + 16.0 * (n * b) as f64;
                rows.push(SweepRow {
                    op: "dense",
                    n,
                    b,
                    precision: prec.name(),
                    ns_per_apply: secs * 1e9 / b as f64,
                    gbps: bytes / secs / 1e9,
                });
            }
        }

        // Symmetric Toeplitz operator of the same order.
        let col: Vec<f64> = (0..n).map(|k| (-0.003 * k as f64).exp()).collect();
        let top = ToeplitzOp::new(col);
        let fft_len = (2 * n).next_power_of_two();
        for &b in bs {
            let x = Mat::from_fn(n, b, |_, _| rng.gaussian());
            for prec in PRECISIONS {
                let secs = time_block(|| top.apply_mat_prec(&x, prec).data[0]);
                let bytes =
                    16.0 * (b * fft_len) as f64 * (2.0 * log2_usize(fft_len) as f64 + 1.0);
                rows.push(SweepRow {
                    op: "toeplitz",
                    n,
                    b,
                    precision: prec.name(),
                    ns_per_apply: secs * 1e9 / b as f64,
                    gbps: bytes / secs / 1e9,
                });
            }
        }

        // 1-D SKI with a grid of the same order as n.
        let pts1: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform_in(0.0, 4.0)]).collect();
        let grid = Grid::covering(&pts1, &[n], 0.05);
        let ski = SkiOp::new(
            &pts1,
            grid,
            SeparableKernel::iso(Shape::Rbf, 1, 0.05, 1.0),
            0.1,
            InterpOrder::Cubic,
            false,
        );
        let nnz = ski.w_matrix().nnz();
        let grid_fft_len = (2 * ski.m()).next_power_of_two();
        for &b in bs {
            let x = Mat::from_fn(n, b, |_, _| rng.gaussian());
            for prec in PRECISIONS {
                let secs = time_block(|| ski.apply_mat_prec(&x, prec).data[0]);
                let csr_bytes = match prec {
                    Precision::F64 => 32.0,
                    Precision::F32F64 => 16.0,
                };
                let bytes = (b as f64)
                    * (csr_bytes * nnz as f64
                        + 16.0
                            * grid_fft_len as f64
                            * (2.0 * log2_usize(grid_fft_len) as f64 + 1.0));
                rows.push(SweepRow {
                    op: "ski",
                    n,
                    b,
                    precision: prec.name(),
                    ns_per_apply: secs * 1e9 / b as f64,
                    gbps: bytes / secs / 1e9,
                });
            }
        }
    }
    rows
}

/// One measured block-CG case for the JSON report.
struct CgSweepRow {
    op: &'static str,
    n: usize,
    rhs: usize,
    block: usize,
    /// RHS-group worker count for this solve (identity field in
    /// `bench_compare.py` — single- and multi-thread rows are gated
    /// separately).
    threads: usize,
    /// MVM precision for the solve's inner iterations (identity field;
    /// `"f32f64"` rows may show different `mvms` than the f64 rows because
    /// refinement restarts cost confirmation applies).
    precision: &'static str,
    ns_per_solve_col: f64,
    mvms: usize,
    block_applies: usize,
    converged: usize,
}

/// Time one full block solve (solves are much slower than single applies,
/// so the rep cap is kept low).
fn time_solve(f: impl FnMut() -> f64) -> f64 {
    time_adaptive(5, 2, 0.4, f)
}

/// Block-CG sweep over the same operator structures as the MVM sweep.
/// The tolerances/noise levels are chosen so the solves converge in tens
/// of iterations — this measures solver throughput trajectory, not GP
/// fidelity. Each (op, n, block) case runs once per thread count in
/// `threads`: at block < RHS the right-hand sides split into several
/// groups, so the multi-thread rows measure the RHS-group fan-out (the
/// solver's results are bit-identical either way, so only
/// `ns_per_solve_col` moves between thread rows).
fn cg_sweep(blocks: &[usize], threads: &[usize]) -> Vec<CgSweepRow> {
    use gpsld::util::precision::Precision;
    const RHS: usize = 8;
    const PRECISIONS: [Precision; 2] = [Precision::F64, Precision::F32F64];
    let mut rows = Vec::new();
    let mut rng = Rng::new(17);
    let push = |op_name: &'static str, n: usize, op: &dyn LinOp, rng: &mut Rng, rows: &mut Vec<CgSweepRow>| {
        let opts_base = CgOptions { tol: 1e-6, max_iters: 120, block_size: 1, ..Default::default() };
        let b = Mat::from_fn(n, RHS, |_, _| rng.gaussian());
        for &blk in blocks {
            for &t in threads {
                for prec in PRECISIONS {
                    // Pin the process default to `t` during the measured
                    // solves so the row's `threads` means the TOTAL worker
                    // budget (operator-internal threading included) — a fair
                    // 1-vs-N comparison on any core count; results are
                    // thread-invariant regardless.
                    let opts = CgOptions {
                        block_size: blk,
                        threads: t,
                        precision: prec,
                        ..opts_base
                    };
                    // Accounting numbers come from the warmup solve
                    // (deterministic, so every rep reports the same counts).
                    let mut acct = None;
                    let secs = gpsld::util::parallel::with_default_threads(t, || {
                        time_solve(|| {
                            let (x, info) = cg_block(op, &b, None, &opts);
                            if acct.is_none() {
                                acct = Some(info);
                            }
                            x.data[0]
                        })
                    });
                    let info = acct.expect("time_solve runs at least once");
                    rows.push(CgSweepRow {
                        op: op_name,
                        n,
                        rhs: RHS,
                        block: blk,
                        threads: t,
                        precision: prec.name(),
                        ns_per_solve_col: secs * 1e9 / RHS as f64,
                        mvms: info.mvms,
                        block_applies: info.block_applies,
                        converged: info.cols.iter().filter(|c| c.converged).count(),
                    });
                }
            }
        }
    };

    // Dense kernel operator (noise bounds the condition number).
    for &n in &[1000usize, 2000] {
        let pts2: Vec<Vec<f64>> =
            (0..n).map(|_| vec![rng.gaussian(), rng.gaussian()]).collect();
        let dense = DenseKernelOp::new(
            pts2,
            Box::new(IsoKernel::new(Shape::Rbf, 2, 0.5, 1.0)),
            1.5,
        );
        push("dense", n, &dense, &mut rng, &mut rows);
    }

    // Shifted symmetric Toeplitz (the shift plays the role of the noise).
    for &n in &[1000usize, 4000] {
        let col: Vec<f64> = (0..n).map(|k| (-0.003 * k as f64).exp()).collect();
        let top = ToeplitzOp::new(col);
        let shifted = ShiftedOp { inner: &top, shift: 10.0 };
        push("toeplitz", n, &shifted, &mut rng, &mut rows);
    }

    // 1-D SKI.
    for &n in &[1000usize, 4000] {
        let pts1: Vec<Vec<f64>> = (0..n).map(|_| vec![rng.uniform_in(0.0, 4.0)]).collect();
        let grid = Grid::covering(&pts1, &[n], 0.05);
        let ski = SkiOp::new(
            &pts1,
            grid,
            SeparableKernel::iso(Shape::Rbf, 1, 0.05, 1.0),
            0.1,
            InterpOrder::Cubic,
            false,
        );
        push("ski", n, &ski, &mut rng, &mut rows);
    }
    rows
}

/// One per-layer trace row for the JSON report (see the `--json-trace`
/// section of the module docs).
struct TraceRow {
    layer: String,
    n: usize,
    calls: u64,
    self_ns_per_run: f64,
    self_share: f64,
    mvms: u64,
    block_applies: u64,
}

/// Fixed traced workload for the trace sweep: preconditioner build + SLQ
/// logdet + preconditioned block solve, all on one dense RBF kernel —
/// together they exercise every instrumented layer (apply sites, Lanczos
/// sessions, probe chunks, `pchol_grow`, `pcg_block`). Deterministic, so
/// the counter columns are exact across machines and runs.
const TRACE_N: usize = 400;

fn trace_workload(op: &DenseKernelOp, b: &Mat) -> f64 {
    use gpsld::solvers::{build_preconditioner, pcg_block, Preconditioner, PrecondOptions};
    let pc = build_preconditioner(op, PrecondOptions::rank(8));
    let est = slq_logdet(
        op,
        &SlqOptions { steps: 15, probes: 8, seed: 5, block_size: 4, ..Default::default() },
    )
    .expect("trace workload slq");
    let opts = CgOptions { tol: 1e-8, max_iters: 200, block_size: 4, ..Default::default() };
    let (x, _info) =
        pcg_block(op, b, None, pc.as_ref().map(|p| p as &dyn Preconditioner), &opts);
    est.value + x.data[0]
}

/// Per-layer self-time shares of the traced workload plus the
/// disabled-mode overhead row. Tracing is observation-only, so running it
/// here cannot perturb the other sweeps' numbers; the registry is reset
/// around the capture and left disabled afterwards.
fn trace_sweep() -> Vec<TraceRow> {
    use gpsld::util::obs;
    let mut rng = Rng::new(23);
    let pts: Vec<Vec<f64>> =
        (0..TRACE_N).map(|_| vec![rng.gaussian(), rng.gaussian()]).collect();
    let op = DenseKernelOp::new(
        pts,
        Box::new(IsoKernel::new(Shape::Rbf, 2, 0.5, 1.0)),
        0.3,
    );
    let b = Mat::from_fn(TRACE_N, 4, |_, _| rng.gaussian());

    // Capture run: one traced execution; the flat by-name rollup of the
    // span snapshot is the per-layer report.
    obs::set_enabled(true);
    obs::reset();
    black_box(trace_workload(&op, &b));
    let stats = obs::snapshot();
    obs::set_enabled(false);
    let mut flat: std::collections::BTreeMap<String, (u64, u64, u64, u64)> =
        std::collections::BTreeMap::new();
    for st in stats.iter().skip(1) {
        let e = flat.entry(st.name.clone()).or_insert((0, 0, 0, 0));
        e.0 += st.calls;
        e.1 += st.self_ns;
        e.2 += st.ctrs[gpsld::util::obs::Counter::Mvms as usize];
        e.3 += st.ctrs[gpsld::util::obs::Counter::BlockApplies as usize];
    }
    let total_self: u64 = flat.values().map(|e| e.1).sum();
    let mut rows: Vec<TraceRow> = flat
        .into_iter()
        .map(|(layer, (calls, self_ns, mvms, block_applies))| TraceRow {
            layer,
            n: TRACE_N,
            calls,
            self_ns_per_run: self_ns as f64,
            self_share: if total_self > 0 {
                self_ns as f64 / total_self as f64
            } else {
                0.0
            },
            mvms,
            block_applies,
        })
        .collect();
    rows.sort_by(|a, b| {
        b.self_ns_per_run
            .partial_cmp(&a.self_ns_per_run)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.layer.cmp(&b.layer))
    });

    // Overhead row: the same workload timed with tracing enabled vs
    // disabled. Clamped at zero — the gate cares about the enabled cost
    // creeping up, not about jitter making "enabled" finish first.
    let dis_secs = time_adaptive(8, 3, 0.3, || trace_workload(&op, &b));
    obs::set_enabled(true);
    obs::reset();
    let en_secs = time_adaptive(8, 3, 0.3, || trace_workload(&op, &b));
    obs::set_enabled(false);
    let overhead_ns = ((en_secs - dis_secs) * 1e9).max(0.0);
    rows.push(TraceRow {
        layer: String::from("tracing_overhead"),
        n: TRACE_N,
        calls: 0,
        self_ns_per_run: overhead_ns,
        self_share: if dis_secs > 0.0 { overhead_ns / (dis_secs * 1e9) } else { 0.0 },
        mvms: 0,
        block_applies: 0,
    });
    rows
}

fn write_trace_json(rows: &[TraceRow], path: &str) {
    let formatted: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"layer\": \"{}\", \"n\": {}, \"calls\": {}, \"self_ns_per_run\": {:.1}, \"self_share\": {:.4}, \"mvms\": {}, \"block_applies\": {}}}",
                r.layer, r.n, r.calls, r.self_ns_per_run, r.self_share, r.mvms, r.block_applies
            )
        })
        .collect();
    write_rows_json(path, &formatted);
}

/// Shared JSON-array writer: each entry is one pre-formatted row object.
fn write_rows_json(path: &str, rows: &[String]) {
    let mut out = String::from("[\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str("  ");
        out.push_str(r);
        out.push_str(if i + 1 < rows.len() { ",\n" } else { "\n" });
    }
    out.push_str("]\n");
    match std::fs::write(path, &out) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => {
            eprintln!("failed to write {path}: {e}");
            std::process::exit(1);
        }
    }
}

/// Serialize the shared precond sweep rows (see
/// `gpsld::coordinator::figures::precond_sweep` — the metric definitions
/// live there, next to the CLI perf table that prints the same sweep).
fn write_precond_json(rows: &[PrecondSweepRow], path: &str) {
    let formatted: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"op\": \"{}\", \"n\": {}, \"sigma\": {}, \"rank\": {}, \"block\": {}, \"threads\": {}, \"cg_iters\": {}, \"converged\": {}, \"lanczos_steps\": {}, \"ns_per_solve_col\": {:.1}}}",
                r.op, r.n, r.sigma, r.rank, r.block, r.threads, r.cg_iters, r.converged, r.lanczos_steps, r.ns_per_solve_col
            )
        })
        .collect();
    write_rows_json(path, &formatted);
}

/// Serialize the shared confidence sweep rows (see
/// `gpsld::coordinator::figures::conf_sweep` — the metric definitions
/// live there, next to the CLI perf table that prints the same sweep).
fn write_conf_json(rows: &[ConfSweepRow], path: &str) {
    let formatted: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"op\": \"{}\", \"n\": {}, \"sigma\": {}, \"tol\": {}, \"probes_used\": {}, \"steps_used\": {}, \"mvms\": {}, \"interval_width\": {:.6}, \"calibrated\": {}, \"ns_per_estimate\": {:.1}}}",
                r.op, r.n, r.sigma, r.tol, r.probes_used, r.steps_used, r.mvms, r.interval_width, r.calibrated, r.ns_per_estimate
            )
        })
        .collect();
    write_rows_json(path, &formatted);
}

/// Serialize the shared service sweep rows (see
/// `gpsld::coordinator::figures::service_sweep` — the metric definitions
/// and the bitwise fused-vs-solo assertions live there, next to the CLI
/// perf table that prints the same sweep). The solo baseline counters
/// stay out of the JSON on purpose: they'd be identity fields to the
/// gate, so solver improvements would orphan every row.
fn write_service_json(rows: &[ServiceSweepRow], path: &str) {
    let formatted: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"model\": \"{}\", \"n\": {}, \"requests\": {}, \"threads\": {}, \"precision\": \"{}\", \"coalesced_cols\": {}, \"solves\": {}, \"block_applies\": {}, \"converged\": {}, \"p50_ns\": {:.1}, \"p99_ns\": {:.1}}}",
                r.model, r.n, r.requests, r.threads, r.precision, r.coalesced_cols,
                r.solves, r.block_applies, r.converged, r.p50_ns, r.p99_ns
            )
        })
        .collect();
    write_rows_json(path, &formatted);
}

fn write_cg_json(rows: &[CgSweepRow], path: &str) {
    let formatted: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"op\": \"{}\", \"n\": {}, \"rhs\": {}, \"block\": {}, \"threads\": {}, \"precision\": \"{}\", \"ns_per_solve_col\": {:.1}, \"mvms\": {}, \"block_applies\": {}, \"converged\": {}}}",
                r.op, r.n, r.rhs, r.block, r.threads, r.precision, r.ns_per_solve_col, r.mvms, r.block_applies, r.converged
            )
        })
        .collect();
    write_rows_json(path, &formatted);
}

fn write_json(rows: &[SweepRow], path: &str) {
    let formatted: Vec<String> = rows
        .iter()
        .map(|r| {
            format!(
                "{{\"op\": \"{}\", \"n\": {}, \"b\": {}, \"precision\": \"{}\", \"ns_per_apply\": {:.1}, \"gbps\": {:.3}}}",
                r.op, r.n, r.b, r.precision, r.ns_per_apply, r.gbps
            )
        })
        .collect();
    write_rows_json(path, &formatted);
}

fn run_smoke(
    json_path: Option<&str>,
    json_cg_path: Option<&str>,
    json_precond_path: Option<&str>,
    json_conf_path: Option<&str>,
    json_service_path: Option<&str>,
    json_trace_path: Option<&str>,
) {
    let rows = block_sweep(&[1000, 4000], &[1, 8, 32]);
    println!(
        "{:<10} {:>6} {:>4} {:>8} {:>14} {:>10}",
        "op", "n", "b", "prec", "ns/apply-col", "eff GB/s"
    );
    for r in &rows {
        println!(
            "{:<10} {:>6} {:>4} {:>8} {:>14.1} {:>10.3}",
            r.op, r.n, r.b, r.precision, r.ns_per_apply, r.gbps
        );
    }
    if let Some(path) = json_path {
        write_json(&rows, path);
    }
    if json_cg_path.is_some() {
        // The 1-vs-N thread sweep: N is fixed (not auto-detected) so row
        // identities stay comparable across machines and runs. block=1
        // splits the 8 RHS into 8 groups — the configuration where the
        // RHS-group fan-out has the most to parallelize.
        let cg_rows = cg_sweep(&[1, 8], &[1, SWEEP_THREADS]);
        println!(
            "{:<10} {:>6} {:>4} {:>6} {:>3} {:>8} {:>16} {:>8} {:>8} {:>6}",
            "op", "n", "rhs", "block", "t", "prec", "ns/solve-col", "mvms", "applies", "conv"
        );
        for r in &cg_rows {
            println!(
                "{:<10} {:>6} {:>4} {:>6} {:>3} {:>8} {:>16.1} {:>8} {:>8} {:>6}",
                r.op, r.n, r.rhs, r.block, r.threads, r.precision, r.ns_per_solve_col,
                r.mvms, r.block_applies, r.converged
            );
        }
        if let Some(path) = json_cg_path {
            write_cg_json(&cg_rows, path);
        }
    }
    if json_precond_path.is_some() {
        let pc_rows = precond_sweep(&[1000], &[0.1, 0.01], &[0, 8, 32], &[1, SWEEP_THREADS]);
        println!(
            "{:<10} {:>6} {:>7} {:>5} {:>3} {:>3} {:>9} {:>5} {:>14} {:>16}",
            "op", "n", "sigma", "rank", "b", "t", "cg_iters", "conv", "lanczos_steps",
            "ns/solve-col"
        );
        for r in &pc_rows {
            println!(
                "{:<10} {:>6} {:>7} {:>5} {:>3} {:>3} {:>9} {:>5} {:>14} {:>16.1}",
                r.op, r.n, r.sigma, r.rank, r.block, r.threads, r.cg_iters, r.converged,
                r.lanczos_steps, r.ns_per_solve_col
            );
        }
        if let Some(path) = json_precond_path {
            write_precond_json(&pc_rows, path);
        }
    }
    if json_conf_path.is_some() {
        let conf_rows = conf_sweep(&[300], &[0.1, 0.01], &[0.0, 60.0, 40.0]);
        println!(
            "{:<10} {:>6} {:>7} {:>6} {:>7} {:>6} {:>6} {:>10} {:>5} {:>16}",
            "op", "n", "sigma", "tol", "probes", "steps", "mvms", "ci_width", "cal",
            "ns/estimate"
        );
        for r in &conf_rows {
            println!(
                "{:<10} {:>6} {:>7} {:>6} {:>7} {:>6} {:>6} {:>10.4} {:>5} {:>16.1}",
                r.op, r.n, r.sigma, r.tol, r.probes_used, r.steps_used, r.mvms,
                r.interval_width, r.calibrated, r.ns_per_estimate
            );
        }
        if let Some(path) = json_conf_path {
            write_conf_json(&conf_rows, path);
        }
    }
    if json_service_path.is_some() {
        // Coalesced request replay: one drain of `requests` single-column
        // variance requests vs. the solo baseline (asserted bitwise-equal
        // inside the sweep). threads is a fixed 1-vs-N identity like the
        // CG sweep.
        let svc_rows = service_sweep(&[512], &[8, 32], &[1, SWEEP_THREADS]);
        println!(
            "{:<10} {:>6} {:>4} {:>3} {:>8} {:>5} {:>7} {:>8} {:>5} {:>12} {:>12}",
            "model", "n", "req", "t", "prec", "cols", "solves", "applies", "conv",
            "p50_ns", "p99_ns"
        );
        for r in &svc_rows {
            println!(
                "{:<10} {:>6} {:>4} {:>3} {:>8} {:>5} {:>7} {:>8} {:>5} {:>12.1} {:>12.1}",
                r.model, r.n, r.requests, r.threads, r.precision, r.coalesced_cols,
                r.solves, r.block_applies, r.converged, r.p50_ns, r.p99_ns
            );
        }
        if let Some(path) = json_service_path {
            write_service_json(&svc_rows, path);
        }
    }
    if json_trace_path.is_some() {
        // Per-layer self-time shares of the fixed traced workload, plus
        // the disabled-mode tracing-overhead row (see the module docs).
        let trace_rows = trace_sweep();
        println!(
            "{:<28} {:>6} {:>8} {:>14} {:>8} {:>8} {:>8}",
            "layer", "n", "calls", "self_ns/run", "share", "mvms", "applies"
        );
        for r in &trace_rows {
            println!(
                "{:<28} {:>6} {:>8} {:>14.1} {:>8.4} {:>8} {:>8}",
                r.layer, r.n, r.calls, r.self_ns_per_run, r.self_share, r.mvms,
                r.block_applies
            );
        }
        if let Some(path) = json_trace_path {
            write_trace_json(&trace_rows, path);
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--smoke") {
        let path_after = |flag: &str| -> Option<String> {
            match args.iter().position(|a| a == flag) {
                Some(i) => match args.get(i + 1) {
                    Some(p) => Some(p.clone()),
                    None => {
                        eprintln!("{flag} needs an output path");
                        std::process::exit(2);
                    }
                },
                None => None,
            }
        };
        let json_path = path_after("--json");
        let json_cg_path = path_after("--json-cg");
        let json_precond_path = path_after("--json-precond");
        let json_conf_path = path_after("--json-conf");
        let json_service_path = path_after("--json-service");
        let json_trace_path = path_after("--json-trace");
        run_smoke(
            json_path.as_deref(),
            json_cg_path.as_deref(),
            json_precond_path.as_deref(),
            json_conf_path.as_deref(),
            json_service_path.as_deref(),
            json_trace_path.as_deref(),
        );
        return;
    }

    let mut b = Bench::new(1.0);
    let mut rng = Rng::new(3);

    // --- Blocked apply_mat sweep (the block-probe engine's headline) ---
    Bench::header("blocked apply_mat sweep (ns per probe-column)");
    let sweep = block_sweep(&[2048], &[1, 8, 32]);
    for r in &sweep {
        println!(
            "{:<28} {:>12.1} ns/col {:>10.3} eff GB/s",
            format!("{}_n{}_b{}_{}", r.op, r.n, r.b, r.precision),
            r.ns_per_apply,
            r.gbps
        );
    }

    // --- SKI MVM scaling in m (paper: O(n + m log m)) ---
    Bench::header("SKI (Toeplitz) MVM, n = 8000");
    let d = data::sound(8000, 3, 40, 9);
    let mut skis = Vec::new();
    for m in [1000usize, 4000, 16000, 64000] {
        let grid = Grid::covering(&d.x_train, &[m], 0.05);
        let ski = SkiOp::new(
            &d.x_train,
            grid,
            SeparableKernel::iso(Shape::Rbf, 1, 0.004, 0.5),
            0.1,
            InterpOrder::Cubic,
            false,
        );
        let x: Vec<f64> = (0..d.n_train()).map(|_| rng.gaussian()).collect();
        let mut y = vec![0.0; d.n_train()];
        b.run(&format!("ski_mvm n=8000 m={m}"), || {
            ski.apply(&x, &mut y);
            black_box(y[0])
        });
        skis.push(ski);
    }

    // --- Estimators end-to-end on SKI m=4000, block-size sweep ---
    Bench::header("logdet estimators on SKI n=8000 m=4000 (3 hypers)");
    let ski = &skis[1];
    for bsz in [1usize, 8, 32] {
        b.run(&format!("slq 25x32 grads block={bsz}"), || {
            black_box(
                slq_logdet(
                    ski,
                    &SlqOptions {
                        steps: 25,
                        probes: 32,
                        seed: 1,
                        block_size: bsz,
                        ..Default::default()
                    },
                )
                .unwrap()
                .value,
            )
        });
    }
    b.run("slq 25x5 value only", || {
        black_box(
            slq_logdet(
                ski,
                &SlqOptions { steps: 25, probes: 5, grads: false, seed: 1, ..Default::default() },
            )
            .unwrap()
            .value,
        )
    });
    b.run("chebyshev 50x5 with grads", || {
        black_box(
            chebyshev_logdet(
                ski,
                &ChebOptions { degree: 50, probes: 5, seed: 1, ..Default::default() },
            )
            .unwrap()
            .value,
        )
    });

    // --- CG solve (the alpha term) + block-CG RHS sweep ---
    Bench::header("CG solve on SKI n=8000 m=4000");
    let rhs: Vec<f64> = (0..d.n_train()).map(|_| rng.gaussian()).collect();
    let cg_opts = CgOptions { tol: 1e-8, max_iters: 500, block_size: 1, ..Default::default() };
    b.run("cg tol=1e-8", || {
        let (x, info) = cg(ski, &rhs, &cg_opts);
        black_box((x[0], info.iters))
    });
    let rhs_blk = Mat::from_fn(d.n_train(), 8, |_, _| rng.gaussian());
    for bsz in [1usize, 8] {
        let opts = CgOptions { block_size: bsz, ..cg_opts };
        b.run(&format!("cg_block 8 rhs block={bsz}"), || {
            let (x, info) = cg_block(ski, &rhs_blk, None, &opts);
            black_box((x.data[0], info.block_applies))
        });
    }

    // --- Dense + PJRT artifact paths (the L1/L2 hot path) ---
    if let Some(res) = cli::run_experiment("perf", Scale::Small) {
        res.print("perf experiment (dense native vs PJRT vs SKI)");
    }

    // --- SKI derivative MVMs (apply_grad hot path) ---
    Bench::header("SKI derivative MVMs");
    let x: Vec<f64> = (0..d.n_train()).map(|_| rng.gaussian()).collect();
    let mut y = vec![0.0; d.n_train()];
    for i in 0..ski.num_hypers() {
        b.run(&format!("apply_grad hyper {i}"), || {
            ski.apply_grad(i, &x, &mut y);
            black_box(y[0])
        });
    }
}
