//! `cargo bench` target regenerating Supp. Fig. 5: Lanczos vs Chebyshev spectrum.
//! Runs the coordinator driver at Small scale; `gpsld exp fig5 --scale paper`
//! reproduces the full-size version.
use gpsld::coordinator::{cli, Scale};
use gpsld::util::bench::Bench;

fn main() {
    Bench::header("Supp. Fig. 5: Lanczos vs Chebyshev spectrum");
    let mut b = Bench::one_shot();
    let mut out = None;
    b.run("fig5 (small scale, end-to-end)", || {
        out = cli::run_experiment("fig5", Scale::Small);
    });
    if let Some(res) = out {
        res.print("Supp. Fig. 5: Lanczos vs Chebyshev spectrum — regenerated rows");
    }
}
