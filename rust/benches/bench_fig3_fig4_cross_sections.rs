//! `cargo bench` target regenerating Supp. Figs. 3-4: 1-D cross sections.
//! Runs the coordinator driver at Small scale; `gpsld exp fig3_fig4 --scale paper`
//! reproduces the full-size version.
use gpsld::coordinator::{cli, Scale};
use gpsld::util::bench::Bench;

fn main() {
    Bench::header("Supp. Figs. 3-4: 1-D cross sections");
    let mut b = Bench::one_shot();
    let mut out = None;
    b.run("fig3_fig4 (small scale, end-to-end)", || {
        out = cli::run_experiment("fig3_fig4", Scale::Small);
    });
    if let Some(res) = out {
        res.print("Supp. Figs. 3-4: 1-D cross sections — regenerated rows");
    }
}
