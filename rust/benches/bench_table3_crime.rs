//! `cargo bench` target regenerating Table 3: crime LGCP (Matern x SM, neg-binomial).
//! Runs the coordinator driver at Small scale; `gpsld exp table3 --scale paper`
//! reproduces the full-size version.
use gpsld::coordinator::{cli, Scale};
use gpsld::util::bench::Bench;

fn main() {
    Bench::header("Table 3: crime LGCP (Matern x SM, neg-binomial)");
    let mut b = Bench::one_shot();
    let mut out = None;
    b.run("table3 (small scale, end-to-end)", || {
        out = cli::run_experiment("table3", Scale::Small);
    });
    if let Some(res) = out {
        res.print("Table 3: crime LGCP (Matern x SM, neg-binomial) — regenerated rows");
    }
}
