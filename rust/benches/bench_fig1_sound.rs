//! `cargo bench` target regenerating Fig. 1: sound modeling (train/infer time, SMAE).
//! Runs the coordinator driver at Small scale; `gpsld exp fig1 --scale paper`
//! reproduces the full-size version.
use gpsld::coordinator::{cli, Scale};
use gpsld::util::bench::Bench;

fn main() {
    Bench::header("Fig. 1: sound modeling (train/infer time, SMAE)");
    let mut b = Bench::one_shot();
    let mut out = None;
    b.run("fig1 (small scale, end-to-end)", || {
        out = cli::run_experiment("fig1", Scale::Small);
    });
    if let Some(res) = out {
        res.print("Fig. 1: sound modeling (train/infer time, SMAE) — regenerated rows");
    }
}
