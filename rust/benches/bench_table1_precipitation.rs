//! `cargo bench` target regenerating Table 1: precipitation MSE + time.
//! Runs the coordinator driver at Small scale; `gpsld exp table1 --scale paper`
//! reproduces the full-size version.
use gpsld::coordinator::{cli, Scale};
use gpsld::util::bench::Bench;

fn main() {
    Bench::header("Table 1: precipitation MSE + time");
    let mut b = Bench::one_shot();
    let mut out = None;
    b.run("table1 (small scale, end-to-end)", || {
        out = cli::run_experiment("table1", Scale::Small);
    });
    if let Some(res) = out {
        res.print("Table 1: precipitation MSE + time — regenerated rows");
    }
}
