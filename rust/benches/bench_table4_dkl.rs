//! `cargo bench` target regenerating Table 4: deep kernel learning RMSE + per-iter time.
//! Runs the coordinator driver at Small scale; `gpsld exp table4 --scale paper`
//! reproduces the full-size version.
use gpsld::coordinator::{cli, Scale};
use gpsld::util::bench::Bench;

fn main() {
    Bench::header("Table 4: deep kernel learning RMSE + per-iter time");
    let mut b = Bench::one_shot();
    let mut out = None;
    b.run("table4 (small scale, end-to-end)", || {
        out = cli::run_experiment("table4", Scale::Small);
    });
    if let Some(res) = out {
        res.print("Table 4: deep kernel learning RMSE + per-iter time — regenerated rows");
    }
}
