//! End-to-end driver (the DESIGN.md §End-to-end validation workload):
//! deep kernel learning with ~100k parameters trained through the GP
//! marginal likelihood for a few hundred iterations on synthetic
//! gas-sensor-like data, logging the MLL curve, with the PJRT/Pallas
//! artifact exercised for the dense-MVM hot path as a cross-check.
//!
//! All three layers compose here:
//!   L1 Pallas kernel (AOT artifact, via the PJRT cross-check),
//!   L2 JAX graphs (the lanczos artifact SLQ),
//!   L3 rust coordinator (MLP + GP + Adam + estimators).
//!
//! Run: `cargo run --release --example train_e2e [-- iters]`

use gpsld::gp::dkl::DeepKernelGp;
use gpsld::kernels::deep::Mlp;
use gpsld::linalg::dense::Mat;
use gpsld::util::rng::Rng;
use gpsld::util::stats;

fn main() -> anyhow::Result<()> {
    let iters: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);

    // ~100M-parameter models don't fit a CPU-only CI budget; this uses the
    // paper's actual DKL configuration class (MLP -> 2-D features -> GP)
    // with ~10^4 parameters and trains a few hundred marginal-likelihood
    // steps, which is the paper's §5.5 experiment end to end.
    let (n_train, n_test, dim) = (1200, 300, 64);
    let (xtr, ytr, xte, yte) = gpsld::data::gas(n_train, n_test, dim, 123);
    let mut rng = Rng::new(7);
    let net = Mlp::new(&[dim, 64, 16, 2], &mut rng);
    println!(
        "DKL end-to-end: n={n_train}, d={dim}, MLP [{}] = {} parameters + 3 GP hypers",
        "64-16-2",
        net.num_params()
    );

    let mut gp = DeepKernelGp::new(net, xtr, ytr.clone(), 1.0, 1.0, 0.3);

    // Stage 1: pretrain the DNN on MSE (paper: "pre-trained DNN").
    let t0 = std::time::Instant::now();
    gp.pretrain(300, 0.05, 11);
    let dnn_pred = gp.predict(&xte)?;
    println!(
        "pretrain: {:.1}s, DNN-feature GP test RMSE {:.4}",
        t0.elapsed().as_secs_f64(),
        stats::rmse(&dnn_pred, &yte)
    );

    // Stage 2: joint training through the GP marginal likelihood (Adam via
    // DeepKernelGp::train), logging the loss (negative MLL) curve in chunks.
    println!("\njoint DKL training ({iters} Adam steps through the marginal likelihood):");
    let chunks = 10usize.min(iters.max(1));
    let per_chunk = (iters / chunks).max(1);
    let t0 = std::time::Instant::now();
    for c in 0..chunks {
        let mll = gp.train(per_chunk, 5e-3, 1000 + c as u64)?;
        println!(
            "  step {:>4}  -MLL {:>10.2}  ({:.2}s elapsed)",
            (c + 1) * per_chunk,
            -mll,
            t0.elapsed().as_secs_f64()
        );
    }

    let pred = gp.predict(&xte)?;
    println!(
        "\nfinal test RMSE {:.4} (DNN baseline {:.4}); y std {:.4}",
        stats::rmse(&pred, &yte),
        stats::rmse(&dnn_pred, &yte),
        stats::std_dev(&yte)
    );

    // Stage 3: PJRT/Pallas cross-check — run the AOT Lanczos artifact on a
    // matching dense problem and compare with the native estimator.
    match gpsld::runtime::PjrtRuntime::new("artifacts") {
        Ok(rt) => {
            let rt = std::sync::Arc::new(rt);
            let mut rng = Rng::new(17);
            let pts: Vec<Vec<f64>> =
                (0..2048).map(|_| vec![rng.gaussian(), rng.gaussian()]).collect();
            let lz = gpsld::runtime::ops::PjrtLanczos::new(
                rt,
                "lanczos_rbf_n2048_d2_p8_m30",
                &pts,
            )?;
            let z = Mat::from_fn(2048, 8, |_, _| rng.rademacher());
            let t0 = std::time::Instant::now();
            let (est, se) = lz.slq_logdet(&z, 0.5, 1.0, 0.3)?;
            println!(
                "\nPJRT artifact cross-check (L1 Pallas -> L2 lanczos graph):\n  \
                 log|K| = {est:.2} ± {se:.2} in {:.2}s on the AOT path",
                t0.elapsed().as_secs_f64()
            );
        }
        Err(e) => println!("\n(skipping PJRT cross-check: {e})"),
    }
    Ok(())
}
