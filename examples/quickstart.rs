//! Quickstart: estimate a GP log determinant and its derivatives with
//! stochastic Lanczos quadrature, compare against the exact answer, and fit
//! kernel hyperparameters by marginal-likelihood optimization.
//!
//! Run: `cargo run --release --example quickstart`

use gpsld::estimators::exact;
use gpsld::estimators::slq::{slq_logdet, SlqOptions};
use gpsld::gp::regression::{Estimator, GpRegression};
use gpsld::kernels::{IsoKernel, Shape};
use gpsld::operators::{DenseKernelOp, KernelOp};
use gpsld::opt::lbfgs::LbfgsOptions;
use gpsld::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 1. A small dataset from a known GP.
    let truth = IsoKernel::new(Shape::Rbf, 1, 0.3, 1.0);
    let data = gpsld::data::gp_1d(400, 0.0, 4.0, false, &truth, 0.15, 42);
    println!(
        "n = {} training points sampled from a GP(ell=0.3, sf=1, sigma=0.15)",
        data.n_train()
    );

    // 2. The kernel operator: only MVMs are ever needed.
    let op = DenseKernelOp::new(
        data.x_train.clone(),
        Box::new(IsoKernel::new(Shape::Rbf, 1, 0.6, 1.5)), // deliberately wrong
        0.4,
    );

    // 3. Log determinant + derivatives by stochastic Lanczos quadrature.
    let est = slq_logdet(
        &op,
        &SlqOptions { steps: 30, probes: 8, seed: 1, ..Default::default() },
    )?;
    let (exact_v, exact_g) = exact::exact_logdet_grads_dense(&op)?;
    println!(
        "\nlog|K|   SLQ: {:>10.3} ± {:.3}   exact: {:>10.3}",
        est.value, est.std_err, exact_v
    );
    for (i, name) in op.hyper_names().iter().enumerate() {
        println!(
            "d/d{name:<10} SLQ: {:>10.3}            exact: {:>10.3}",
            est.grad[i], exact_g[i]
        );
    }
    println!("(MVMs consumed: {})", est.mvms);

    // 4. Kernel learning: maximize the marginal likelihood with L-BFGS,
    //    logdet + derivatives supplied by SLQ.
    let mut gp = GpRegression::new(op, data.y_train.clone());
    gp.mean = 0.0;
    let stats = gp.train(
        &Estimator::Slq(SlqOptions { steps: 30, probes: 6, seed: 2, ..Default::default() }),
        &LbfgsOptions { max_iters: 30, ..Default::default() },
    )?;
    let h = &stats.final_hypers;
    println!(
        "\nrecovered hypers: ell={:.3} sf={:.3} sigma={:.3}   (truth 0.3 / 1.0 / 0.15)",
        h[0].exp(),
        h[1].exp(),
        h[2].exp()
    );
    println!(
        "final MLL {:.2} after {} L-BFGS iterations ({:.2}s)",
        stats.final_mll, stats.opt.iters, stats.seconds
    );

    // 5. Predict at held-out locations.
    let mut rng = Rng::new(7);
    let test: Vec<Vec<f64>> = (0..5).map(|_| vec![rng.uniform_in(0.0, 4.0)]).collect();
    let mean = gp.predict_mean(&test);
    let var = gp.predict_var(&test);
    println!("\npredictions:");
    for i in 0..test.len() {
        println!("  f({:.3}) = {:>7.3} ± {:.3}", test[i][0], mean[i], var[i].sqrt());
    }
    Ok(())
}
