//! Sound inpainting (paper §5.1): recover contiguous missing regions of an
//! audio-like waveform with Toeplitz-SKI fast MVMs and SLQ kernel learning.
//!
//! Run: `cargo run --release --example sound_inpainting [-- n m]`

use gpsld::estimators::slq::SlqOptions;
use gpsld::gp::regression::{Estimator, GpRegression};
use gpsld::grid::{Grid, InterpOrder};
use gpsld::kernels::{SeparableKernel, Shape};
use gpsld::operators::SkiOp;
use gpsld::opt::lbfgs::LbfgsOptions;
use gpsld::util::stats;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(12_000);
    let m: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(3000);

    let d = gpsld::data::sound(n, 5, 80, 42);
    println!(
        "sound inpainting: {} train, {} test (missing gap) points, m = {m} inducing",
        d.n_train(),
        d.n_test()
    );

    let grid = Grid::covering(&d.x_train, &[m], 0.05);
    let ski = SkiOp::new(
        &d.x_train,
        grid,
        SeparableKernel::iso(Shape::Rbf, 1, 0.004, 0.5),
        0.1,
        InterpOrder::Cubic,
        false,
    );
    println!(
        "SKI operator: n = {}, m = {} (Toeplitz K_UU, W nnz/row = 4)",
        d.n_train(),
        m
    );

    let mut gp = GpRegression::new(ski, d.y_train.clone());
    let t0 = std::time::Instant::now();
    let stats_t = gp.train(
        &Estimator::Slq(SlqOptions { steps: 25, probes: 5, seed: 1, ..Default::default() }),
        &LbfgsOptions { max_iters: 12, g_tol: 1e-3, ..Default::default() },
    )?;
    println!(
        "hyper learning (SLQ, 25 steps x 5 probes): {:.2}s, MLL {:.1}",
        t0.elapsed().as_secs_f64(),
        stats_t.final_mll
    );
    let h = &stats_t.final_hypers;
    println!(
        "  learned ell = {:.5}, sf = {:.3}, sigma = {:.3}",
        h[0].exp(),
        h[1].exp(),
        h[2].exp()
    );

    let t0 = std::time::Instant::now();
    let pred = gp.predict_mean(&d.x_test);
    println!(
        "inference on {} gap points: {:.3}s, SMAE = {:.3} (1.0 = constant-mean baseline)",
        d.n_test(),
        t0.elapsed().as_secs_f64(),
        stats::smae(&pred, &d.y_test)
    );
    Ok(())
}
