//! Crime-rate forecasting (paper §5.4): log-Gaussian Cox process with a
//! negative-binomial likelihood and Matérn×spectral-mixture kernel over a
//! space-time count grid; the Laplace approximation's `log|B|` comes from
//! stochastic Lanczos quadrature — the setting where the scaled-eigenvalue
//! baseline needs the (misspecified) Fiedler bound.
//!
//! Run: `cargo run --release --example crime_lgcp`

use gpsld::coordinator::{experiments, Scale};

fn main() {
    println!("reproducing Table 3 (crime LGCP), small scale;");
    println!("use `gpsld exp table3 --scale paper` for the full grid\n");
    let res = experiments::table3_crime(Scale::Small);
    res.print("Table 3 — Chicago-style crime LGCP (synthetic substitute)");
    println!(
        "\nshape check vs paper: the Fiedler/scaled-eig variant recovers\n\
         different (typically more extreme) hypers than Lanczos while RMSEs\n\
         stay close — the misspecification the paper reports."
    );
}
