//! Precipitation interpolation (paper §5.2): 3-D space-time SKI (Kronecker
//! of Toeplitz factors) with SLQ kernel learning, vs the scaled-eigenvalue
//! baseline and an exact-subset GP.
//!
//! Run: `cargo run --release --example precipitation`

use gpsld::coordinator::{experiments, Scale};

fn main() {
    println!("reproducing Table 1 (daily precipitation), small scale;");
    println!("use `gpsld exp table1 --scale paper` for larger n/m\n");
    let res = experiments::table1_precipitation(Scale::Small);
    res.print("Table 1 — precipitation (synthetic space-time substitute)");
    println!(
        "\nshape check vs paper: Lanczos and scaled-eig reach similar MSE on\n\
         the full data (scaled-eig is viable here because K_UU has fast\n\
         eigendecompositions), both beating the subset-exact GP; Lanczos is\n\
         not slower than scaled-eig."
    );
}
