#!/usr/bin/env python3
"""Diff two BENCH_*.json runs and fail loudly on regressions.

Usage: bench_compare.py PREV.json CURRENT.json [--threshold 0.20]

Rows are JSON objects; the identity of a row is every non-metric field
(op, n, b, rhs, block, threads, sigma, rank, ...), and the compared
metrics are the timing fields (ns_per_apply / ns_per_solve_col — lower is
better) plus the work counters (mvms / block_applies / cg_iters /
lanczos_steps — lower is better, and far less noisy than wall time). In
particular `threads` is an identity field, NOT a metric: the single- and
multi-thread rows of the 1-vs-N sweep are gated separately, so a
multi-thread speedup can never mask (or be mistaken for) a single-thread
regression. A current row whose metric exceeds the previous run's by more
than the threshold fraction is a regression; the script prints every
regression and exits 2 so CI and scripts/bench_smoke.sh stop on it. Rows
present in only one run are reported but not fatal (sweeps grow over
time).
"""

import json
import sys

# Lower-is-better metrics. Timing is noisy; counters are exact.
TIMING_METRICS = ("ns_per_apply", "ns_per_solve_col")
COUNTER_METRICS = ("mvms", "block_applies", "cg_iters", "lanczos_steps")
# Higher-is-better, exact: ANY drop is a regression (a solve that stops
# converging often also gets *faster*, so the timing gate alone would
# count the breakage as an improvement).
HIGHER_BETTER = ("converged",)
# Fields that are measurements rather than identity, but not compared.
# Everything else — including `threads` — is identity: a (op, n, block,
# threads=1) row only ever compares against its threads=1 baseline.
NON_IDENTITY = set(TIMING_METRICS) | set(COUNTER_METRICS) | set(HIGHER_BETTER) | {"gbps"}


def row_key(row):
    return tuple(sorted((k, v) for k, v in row.items() if k not in NON_IDENTITY))


def load(path):
    with open(path) as f:
        rows = json.load(f)
    if not isinstance(rows, list):
        sys.exit(f"bench_compare: {path} is not a JSON array")
    out = {}
    for row in rows:
        key = row_key(row)
        if key in out:
            sys.exit(f"bench_compare: duplicate row identity in {path}: {key}")
        out[key] = row
    return out


def fmt_key(key):
    return " ".join(f"{k}={v}" for k, v in key)


def main(argv):
    threshold = 0.20
    args = []
    i = 0
    while i < len(argv):
        a = argv[i]
        if a == "--threshold" or a.startswith("--threshold="):
            if "=" in a:
                threshold = float(a.split("=", 1)[1])
            elif i + 1 < len(argv):
                threshold = float(argv[i + 1])
                i += 1
            else:
                sys.exit(f"bench_compare: --threshold needs a value\n{__doc__}")
        elif a.startswith("--"):
            sys.exit(f"bench_compare: unknown flag {a}\n{__doc__}")
        else:
            args.append(a)
        i += 1
    if len(args) != 2:
        sys.exit(__doc__)
    prev, cur = load(args[0]), load(args[1])

    regressions = []
    improvements = 0
    matched = 0
    for key, crow in cur.items():
        prow = prev.get(key)
        if prow is None:
            print(f"bench_compare: new row (no baseline): {fmt_key(key)}")
            continue
        matched += 1
        for metric in TIMING_METRICS + COUNTER_METRICS:
            if metric not in crow or metric not in prow:
                continue
            old, new = float(prow[metric]), float(crow[metric])
            if old < 0:
                continue
            if old == 0:
                # A zero baseline must not disable the gate: any rise from
                # exactly 0 (e.g. a trivially-converged count) is flagged.
                if new > 0:
                    regressions.append(
                        f"  {fmt_key(key)}: {metric} rose from 0 -> {new:g}"
                    )
                continue
            rel = (new - old) / old
            if rel > threshold:
                regressions.append(
                    f"  {fmt_key(key)}: {metric} {old:g} -> {new:g} (+{100 * rel:.1f}%)"
                )
            elif rel < -threshold:
                improvements += 1
        for metric in HIGHER_BETTER:
            if metric not in crow or metric not in prow:
                continue
            old, new = float(prow[metric]), float(crow[metric])
            if new < old:
                regressions.append(
                    f"  {fmt_key(key)}: {metric} dropped {old:g} -> {new:g}"
                )
    for key in prev:
        if key not in cur:
            print(f"bench_compare: row disappeared from current run: {fmt_key(key)}")

    if prev and matched == 0:
        # A schema change (new identity field) makes every row "new" — and
        # a broken bench can emit zero rows — and either would otherwise
        # pass vacuously, letting bench_smoke.sh rotate the old baseline
        # away on a trivially-green run. Make the operator acknowledge the
        # re-baseline explicitly, and only for the affected file stems so
        # the unchanged files stay gated.
        stem = args[1].rsplit("/", 1)[-1].split(".", 1)[0]
        print(
            f"bench_compare: NO rows of {args[1]} match any baseline row in "
            f"{args[0]} — the row identity schema changed (or the bench "
            "emitted nothing); nothing was gated. Re-baseline deliberately "
            f'with BENCH_SKIP_COMPARE="{stem}" (space-separate several '
            "stems; plain BENCH_SKIP_COMPARE=1 skips EVERY file).",
            file=sys.stderr,
        )
        sys.exit(2)

    if regressions:
        print(
            f"bench_compare: {len(regressions)} regression(s) over "
            f"{100 * threshold:.0f}% vs {args[0]}:",
            file=sys.stderr,
        )
        for r in regressions:
            print(r, file=sys.stderr)
        sys.exit(2)
    print(
        f"bench_compare: OK — {len(cur)} rows vs {args[0]}, "
        f"{improvements} improvement(s), no regression over {100 * threshold:.0f}%"
    )


if __name__ == "__main__":
    main(sys.argv[1:])
