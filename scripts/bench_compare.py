#!/usr/bin/env python3
"""Diff two BENCH_*.json runs and fail loudly on regressions.

Usage: bench_compare.py PREV.json CURRENT.json [--threshold 0.20] [--min-ns 50]
       bench_compare.py --self-test

Rows are JSON objects; the identity of a row is every non-metric field
(op, n, b, rhs, block, threads, precision, sigma, rank, tol, ...), and
the compared metrics are the timing fields (ns_per_apply /
ns_per_solve_col / ns_per_estimate — lower is better) plus the work
counters (mvms / block_applies / cg_iters / lanczos_steps / probes_used /
steps_used — lower is better, and far less noisy than wall time). In
particular `threads` and `precision` are identity fields, NOT metrics:
the single- and multi-thread rows of the 1-vs-N sweep (and the f64 vs
f32f64 rows of the precision sweep) are gated separately, so a speedup on
one configuration can never mask (or be mistaken for) a regression on
another. A current row whose metric exceeds the previous run's by more
than the threshold fraction is a regression; the script prints every
regression and exits 2 so CI and scripts/bench_smoke.sh stop on it. Rows
present in only one run are reported but not fatal (sweeps grow over
time).

TIMING metrics additionally honor a minimum-time floor (`--min-ns`,
default 50 ns): when the absolute rise `new - old` is under the floor,
the relative gate does not fire. Sub-floor rows time single cheap
operations where a fixed scheduling/allocator hiccup of a few dozen ns
easily exceeds 20% *relatively* while meaning nothing — the floor keeps
the gate sharp on the rows where 20% is real work. Counters are exact and
get no floor.

`--self-test` runs the built-in unit checks (row identity, both gate
directions, the floor, the zero-baseline and no-matching-rows paths) and
exits 0/1 — invoked by scripts/bench_smoke.sh before any real gating so a
broken comparator fails the smoke run instead of green-lighting it.
"""

import json
import sys

# Lower-is-better metrics. Timing is noisy; counters are exact.
# p50_ns/p99_ns are the serving layer's per-request latency quantiles
# (BENCH_service): timing-class, so they honor the ns floor.
# self_ns_per_run is the trace sweep's per-layer self time (BENCH_trace) —
# timing-class too, and the floor also silences its tracing_overhead row
# when the enabled-vs-disabled difference is down in the jitter.
TIMING_METRICS = (
    "ns_per_apply",
    "ns_per_solve_col",
    "ns_per_estimate",
    "p50_ns",
    "p99_ns",
    "self_ns_per_run",
)
COUNTER_METRICS = (
    "mvms",
    "block_applies",
    "cg_iters",
    "lanczos_steps",
    "probes_used",
    "steps_used",
    # Block solves dispatched by the coalescing service (BENCH_service):
    # coalescing regressing into per-request solves fires here exactly.
    "solves",
    # Span entries per layer in the trace sweep (BENCH_trace): the traced
    # workload is deterministic, so more calls means more iterations of
    # real work, not noise.
    "calls",
)
# Higher-is-better, exact: ANY drop is a regression (a solve that stops
# converging often also gets *faster*, so the timing gate alone would
# count the breakage as an improvement; an adaptive logdet that stops
# being calibrated also uses *fewer* probes, so probes_used alone would
# count the miscalibration as an improvement).
HIGHER_BETTER = ("converged", "calibrated")
# Fields that are measurements rather than identity, but not compared.
# Everything else — including `threads` and `tol` — is identity: a
# (op, n, block, threads=1) row only ever compares against its threads=1
# baseline, and a tol=0.25 adaptive row never against the fixed-budget
# tol=0 row. interval_width is informational: it tracks the requested tol
# by construction on adaptive rows, so gating it would double-count the
# calibrated/probes_used signals.
# self_share (BENCH_trace) is informational like interval_width: shares
# reshuffle whenever ANY layer speeds up, so gating them would flag
# improvements elsewhere as regressions here.
NON_IDENTITY = (
    set(TIMING_METRICS)
    | set(COUNTER_METRICS)
    | set(HIGHER_BETTER)
    | {"gbps", "interval_width", "self_share"}
)


def row_key(row):
    return tuple(sorted((k, v) for k, v in row.items() if k not in NON_IDENTITY))


def load(path):
    with open(path) as f:
        rows = json.load(f)
    if not isinstance(rows, list):
        sys.exit(f"bench_compare: {path} is not a JSON array")
    out = {}
    for row in rows:
        key = row_key(row)
        if key in out:
            sys.exit(f"bench_compare: duplicate row identity in {path}: {key}")
        out[key] = row
    return out


def fmt_key(key):
    return " ".join(f"{k}={v}" for k, v in key)


def compare(prev, cur, threshold, min_ns):
    """Gate `cur` rows against `prev`; pure so --self-test can drive it.

    Returns (regressions, improvements, matched): the regression message
    list, the count of metrics that improved past the threshold, and the
    number of current rows that had a baseline row to compare against.
    """
    regressions = []
    improvements = 0
    matched = 0
    for key, crow in cur.items():
        prow = prev.get(key)
        if prow is None:
            print(f"bench_compare: new row (no baseline): {fmt_key(key)}")
            continue
        matched += 1
        for metric in TIMING_METRICS + COUNTER_METRICS:
            if metric not in crow or metric not in prow:
                continue
            old, new = float(prow[metric]), float(crow[metric])
            if old < 0:
                continue
            if old == 0:
                # A zero baseline must not disable the gate: any rise from
                # exactly 0 (e.g. a trivially-converged count) is flagged.
                # Counters only — a timing rise from 0 under the ns floor
                # is the same sub-resolution noise the floor exists for.
                if new > 0 and not (metric in TIMING_METRICS and new < min_ns):
                    regressions.append(
                        f"  {fmt_key(key)}: {metric} rose from 0 -> {new:g}"
                    )
                continue
            rel = (new - old) / old
            if metric in TIMING_METRICS and abs(new - old) < min_ns:
                # Sub-floor absolute move: too small to distinguish from
                # scheduler/allocator jitter on cheap rows, never a
                # regression no matter how large relatively — and a
                # sub-floor drop likewise doesn't count as an improvement.
                continue
            if rel > threshold:
                regressions.append(
                    f"  {fmt_key(key)}: {metric} {old:g} -> {new:g} (+{100 * rel:.1f}%)"
                )
            elif rel < -threshold:
                improvements += 1
        for metric in HIGHER_BETTER:
            if metric not in crow or metric not in prow:
                continue
            old, new = float(prow[metric]), float(crow[metric])
            if new < old:
                regressions.append(
                    f"  {fmt_key(key)}: {metric} dropped {old:g} -> {new:g}"
                )
    for key in prev:
        if key not in cur:
            print(f"bench_compare: row disappeared from current run: {fmt_key(key)}")
    return regressions, improvements, matched


def main(argv):
    threshold = 0.20
    min_ns = 50.0
    args = []
    i = 0
    while i < len(argv):
        a = argv[i]
        if a == "--self-test":
            sys.exit(self_test())
        elif a == "--threshold" or a.startswith("--threshold="):
            if "=" in a:
                threshold = float(a.split("=", 1)[1])
            elif i + 1 < len(argv):
                threshold = float(argv[i + 1])
                i += 1
            else:
                sys.exit(f"bench_compare: --threshold needs a value\n{__doc__}")
        elif a == "--min-ns" or a.startswith("--min-ns="):
            if "=" in a:
                min_ns = float(a.split("=", 1)[1])
            elif i + 1 < len(argv):
                min_ns = float(argv[i + 1])
                i += 1
            else:
                sys.exit(f"bench_compare: --min-ns needs a value\n{__doc__}")
        elif a.startswith("--"):
            sys.exit(f"bench_compare: unknown flag {a}\n{__doc__}")
        else:
            args.append(a)
        i += 1
    if len(args) != 2:
        sys.exit(__doc__)
    prev, cur = load(args[0]), load(args[1])

    regressions, improvements, matched = compare(prev, cur, threshold, min_ns)

    if prev and matched == 0:
        # A schema change (new identity field) makes every row "new" — and
        # a broken bench can emit zero rows — and either would otherwise
        # pass vacuously, letting bench_smoke.sh rotate the old baseline
        # away on a trivially-green run. Make the operator acknowledge the
        # re-baseline explicitly, and only for the affected file stems so
        # the unchanged files stay gated.
        stem = args[1].rsplit("/", 1)[-1].split(".", 1)[0]
        print(
            f"bench_compare: NO rows of {args[1]} match any baseline row in "
            f"{args[0]} — the row identity schema changed (or the bench "
            "emitted nothing); nothing was gated. Re-baseline deliberately "
            f'with BENCH_SKIP_COMPARE="{stem}" (space-separate several '
            "stems; plain BENCH_SKIP_COMPARE=1 skips EVERY file).",
            file=sys.stderr,
        )
        sys.exit(2)

    if regressions:
        print(
            f"bench_compare: {len(regressions)} regression(s) over "
            f"{100 * threshold:.0f}% vs {args[0]}:",
            file=sys.stderr,
        )
        for r in regressions:
            print(r, file=sys.stderr)
        sys.exit(2)
    print(
        f"bench_compare: OK — {len(cur)} rows vs {args[0]}, "
        f"{improvements} improvement(s), no regression over {100 * threshold:.0f}%"
    )


def self_test():
    """Unit checks over `compare` with synthetic rows; 0 on pass.

    Covers exactly the properties bench_smoke.sh relies on: identity
    separation (threads/precision), both gate directions, the timing
    floor, counter exactness, the converged drop, the zero baseline, and
    the matched==0 schema-change signal.
    """

    def rows(*rws):
        return {row_key(r): r for r in rws}

    checks = 0

    # Identity: threads and precision split rows; a fast f32f64 row must
    # not be matched against (and so can't mask) a slow f64 row.
    base = {"op": "dense", "n": 512, "b": 8, "threads": 1, "precision": "f64"}
    other = dict(base, precision="f32f64")
    assert row_key(base) != row_key(other)
    _, _, matched = compare(
        rows(dict(base, ns_per_apply=1000.0)),
        rows(dict(other, ns_per_apply=100.0)),
        0.20,
        50.0,
    )
    assert matched == 0
    checks += 1

    # Timing regression above threshold AND above the ns floor fires.
    reg, imp, matched = compare(
        rows(dict(base, ns_per_apply=1000.0)),
        rows(dict(base, ns_per_apply=1400.0)),
        0.20,
        50.0,
    )
    assert matched == 1 and len(reg) == 1 and imp == 0, reg
    checks += 1

    # Same 40% relative rise, but 12 ns absolute: under the floor, quiet.
    reg, imp, _ = compare(
        rows(dict(base, ns_per_apply=30.0)),
        rows(dict(base, ns_per_apply=42.0)),
        0.20,
        50.0,
    )
    assert reg == [] and imp == 0, reg
    checks += 1

    # ... and with the floor disabled the same rise fires again.
    reg, _, _ = compare(
        rows(dict(base, ns_per_apply=30.0)),
        rows(dict(base, ns_per_apply=42.0)),
        0.20,
        0.0,
    )
    assert len(reg) == 1, reg
    checks += 1

    # A real improvement (past threshold and floor) is counted, not flagged.
    reg, imp, _ = compare(
        rows(dict(base, ns_per_apply=1000.0)),
        rows(dict(base, ns_per_apply=600.0)),
        0.20,
        50.0,
    )
    assert reg == [] and imp == 1
    checks += 1

    # Counters are exact: no floor, a 25% iteration-count rise fires even
    # though the absolute rise (2) is tiny.
    reg, _, _ = compare(
        rows(dict(base, cg_iters=8)),
        rows(dict(base, cg_iters=10)),
        0.20,
        50.0,
    )
    assert len(reg) == 1, reg
    checks += 1

    # converged is higher-better and exact: any drop fires.
    reg, _, _ = compare(
        rows(dict(base, converged=1)),
        rows(dict(base, converged=0)),
        0.20,
        50.0,
    )
    assert len(reg) == 1, reg
    checks += 1

    # calibrated (BENCH_conf) is higher-better and exact: an interval that
    # stops covering the exact logdet fires even though the run also got
    # cheaper (fewer probes, faster wall time).
    conf = {"op": "dense_rbf", "n": 300, "sigma": 0.1, "tol": 0.25}
    reg, _, _ = compare(
        rows(dict(conf, calibrated=1, probes_used=12, ns_per_estimate=5e6)),
        rows(dict(conf, calibrated=0, probes_used=6, ns_per_estimate=3e6)),
        0.20,
        50.0,
    )
    assert len(reg) == 1 and "calibrated" in reg[0], reg
    checks += 1

    # probes_used is an exact lower-is-better counter: an adaptive run
    # needing 25% more probes fires; interval_width is informational and
    # never gated (and never splits row identity).
    reg, _, matched = compare(
        rows(dict(conf, probes_used=8, interval_width=0.40)),
        rows(dict(conf, probes_used=10, interval_width=0.10)),
        0.20,
        50.0,
    )
    assert matched == 1 and len(reg) == 1 and "probes_used" in reg[0], reg
    checks += 1

    # steps_used is likewise exact lower-is-better: the two-axis driver
    # deepening its Lanczos sessions 25% past the baseline fires even when
    # the probe count is unchanged (a probes-only gate would miss the
    # second budget axis entirely).
    reg, _, _ = compare(
        rows(dict(conf, probes_used=8, steps_used=12)),
        rows(dict(conf, probes_used=8, steps_used=15)),
        0.20,
        50.0,
    )
    assert len(reg) == 1 and "steps_used" in reg[0], reg
    checks += 1

    # mvms is the two-axis driver's total-cost counter (BENCH_conf, also
    # BENCH_cg): it gates exactly like the other exact counters, so a
    # driver that reaches its tolerance by burning more operator applies
    # fires even when probes_used and steps_used both look fine.
    reg, _, _ = compare(
        rows(dict(conf, mvms=100)),
        rows(dict(conf, mvms=130)),
        0.20,
        50.0,
    )
    assert len(reg) == 1 and "mvms" in reg[0], reg
    checks += 1

    # tol is identity, not a metric: an adaptive row (tol != 0) never
    # compares against the fixed-budget tol=0 row — "adaptive must not
    # out-spend the fixed reference" is asserted inside the sweep itself,
    # not synthesized by the bench diff. Changing the sweep's tolerance
    # grid therefore orphans the adaptive rows (matched == 0 when no row
    # survives), which main() turns into the explicit re-baseline error
    # instead of a vacuously green run.
    _, _, matched = compare(
        rows(dict(conf, tol=0, probes_used=16)),
        rows(dict(conf, probes_used=64)),
        0.20,
        50.0,
    )
    assert matched == 0
    checks += 1

    # Zero baseline: a counter rising from exactly 0 fires; a timing
    # metric rising from 0 to under the floor stays quiet.
    reg, _, _ = compare(
        rows(dict(base, cg_iters=0)),
        rows(dict(base, cg_iters=1)),
        0.20,
        50.0,
    )
    assert len(reg) == 1, reg
    reg, _, _ = compare(
        rows(dict(base, ns_per_apply=0.0)),
        rows(dict(base, ns_per_apply=20.0)),
        0.20,
        50.0,
    )
    assert reg == [], reg
    checks += 1

    # BENCH_service: `solves` is an exact lower-is-better counter — the
    # coalescing layer regressing from 1 fused solve into per-request
    # solves fires even though each solo solve is individually fast.
    svc = {
        "model": "dense_rbf",
        "n": 512,
        "requests": 32,
        "threads": 1,
        "precision": "f64",
        "coalesced_cols": 32,
    }
    reg, _, matched = compare(
        rows(dict(svc, solves=1, converged=32)),
        rows(dict(svc, solves=32, converged=32)),
        0.20,
        50.0,
    )
    assert matched == 1 and len(reg) == 1 and "solves" in reg[0], reg
    checks += 1

    # Service latency quantiles are timing-class: a large relative rise
    # under the ns floor stays quiet, a real p99 blowup fires, and a
    # converged drop fires even when the latencies improve.
    reg, _, _ = compare(
        rows(dict(svc, p50_ns=30.0, p99_ns=40.0)),
        rows(dict(svc, p50_ns=45.0, p99_ns=60.0)),
        0.20,
        50.0,
    )
    assert reg == [], reg
    reg, _, _ = compare(
        rows(dict(svc, p50_ns=2e5, p99_ns=1e6)),
        rows(dict(svc, p50_ns=2e5, p99_ns=2e6)),
        0.20,
        50.0,
    )
    assert len(reg) == 1 and "p99_ns" in reg[0], reg
    reg, _, _ = compare(
        rows(dict(svc, converged=32, p50_ns=2e5, p99_ns=1e6)),
        rows(dict(svc, converged=30, p50_ns=1e5, p99_ns=5e5)),
        0.20,
        50.0,
    )
    assert len(reg) == 1 and "converged" in reg[0], reg
    checks += 1

    # BENCH_trace: `layer` is identity — the slq layer's rows never gate
    # against pcg_block's; self_ns_per_run is timing-class (floored);
    # calls/mvms are exact counters; self_share is informational and never
    # gated nor identity (a share reshuffle alone must not orphan or flag
    # the row).
    trace = {"layer": "slq", "n": 400}
    other_layer = {"layer": "pcg_block", "n": 400}
    assert row_key(trace) != row_key(other_layer)
    reg, _, matched = compare(
        rows(dict(trace, self_ns_per_run=1e6, self_share=0.50, calls=8, mvms=120)),
        rows(dict(trace, self_ns_per_run=2e6, self_share=0.20, calls=8, mvms=120)),
        0.20,
        50.0,
    )
    assert matched == 1 and len(reg) == 1 and "self_ns_per_run" in reg[0], reg
    reg, _, matched = compare(
        rows(dict(trace, self_ns_per_run=1e6, self_share=0.50, calls=8, mvms=120)),
        rows(dict(trace, self_ns_per_run=1e6, self_share=0.10, calls=8, mvms=150)),
        0.20,
        50.0,
    )
    assert matched == 1 and len(reg) == 1 and "mvms" in reg[0], reg
    reg, _, _ = compare(
        rows(dict(trace, calls=8)),
        rows(dict(trace, calls=10)),
        0.20,
        50.0,
    )
    assert len(reg) == 1 and "calls" in reg[0], reg
    # The tracing_overhead row: a sub-floor enabled-vs-disabled difference
    # (including one rising from the clamped 0) stays quiet; a real
    # overhead blowup fires.
    ovh = {"layer": "tracing_overhead", "n": 400}
    reg, _, _ = compare(
        rows(dict(ovh, self_ns_per_run=0.0)),
        rows(dict(ovh, self_ns_per_run=40.0)),
        0.20,
        50.0,
    )
    assert reg == [], reg
    reg, _, _ = compare(
        rows(dict(ovh, self_ns_per_run=1e3)),
        rows(dict(ovh, self_ns_per_run=1e5)),
        0.20,
        50.0,
    )
    assert len(reg) == 1 and "self_ns_per_run" in reg[0], reg
    checks += 1

    # Schema change (new identity field on every row) -> matched == 0,
    # which main() turns into the explicit re-baseline error.
    _, _, matched = compare(
        rows(dict(base, ns_per_apply=1000.0)),
        rows(dict(base, new_field="x", ns_per_apply=1000.0)),
        0.20,
        50.0,
    )
    assert matched == 0
    checks += 1

    print(f"bench_compare: self-test OK ({checks} checks)")
    return 0


if __name__ == "__main__":
    main(sys.argv[1:])
