#!/usr/bin/env bash
# Perf smoke: run the blocked-MVM sweep (dense / Toeplitz / SKI at
# n in {1k, 4k}, b in {1, 8, 32}), the block-CG solve sweep (same
# operator structures, 8 RHS, block in {1, 8}, RHS-group threads in
# {1, 4} — the 1-vs-N thread sweep; multi-thread rows should sit strictly
# below their single-thread twins on the multi-group configurations), and
# the pivoted-Cholesky preconditioning sweep (rank x sigma x threads on an
# ill-conditioned dense RBF), and the confidence/adaptive-budget sweep
# (tolerance x sigma on the same kernel: probes AND Lanczos steps used by
# the two-axis driver, total MVMs, interval widths, and calibration
# against the exact logdet — the sweep itself asserts that deepening beat
# the probes-only driver on the hard-sigma rows), and the
# streaming-service request-replay sweep (coalesced variance requests at
# both solve precisions: fused solves, blocked applies, convergence,
# p50/p99 request latency — the sweep itself asserts
# the fused answers bitwise-equal the solo baseline), and the trace sweep
# (per-layer self-time shares of a fixed traced workload under the
# util::obs span registry, plus a disabled-mode tracing-overhead row so
# instrumentation cost creep fails the gate), emitting
# BENCH_mvm.json, BENCH_cg.json, BENCH_precond.json, BENCH_conf.json,
# BENCH_service.json, and BENCH_trace.json at the repo root so successive
# PRs have a throughput trajectory — MVMs, solves, thread scaling,
# preconditioned iteration counts, adaptive probe budgets, serving
# amortization, and per-layer time shares — to compare against.
#
# When a previous BENCH_*.json exists it is rotated to BENCH_*.prev.json
# and diffed against the fresh run with scripts/bench_compare.py, which
# fails loudly (exit 2) on >20% regressions in timing or iteration/MVM
# counts (timing rises under the 50 ns absolute floor are jitter, not
# regressions — see --min-ns in bench_compare.py) — or when ZERO rows
# match the baseline (a row-identity schema change must be re-baselined
# deliberately, not rotated in on a vacuously green run; the `precision`
# identity column added by the mixed-precision PR needs
# BENCH_SKIP_COMPARE="BENCH_mvm BENCH_cg" exactly once). The two-axis
# adaptive PR reshaped the conf sweep (seed step budget 40 -> 10,
# reachable tolerances, new `mvms` column): a BENCH_conf baseline
# predating it (no "mvms" key) is re-baselined automatically, exactly
# once — freshly-formatted baselines stay gated as usual.
# Set BENCH_SKIP_COMPARE=1 to suppress the gate for ALL files (e.g. when
# moving between machines, where wall-clock baselines are meaningless), or
# to a space-separated list of file stems (BENCH_SKIP_COMPARE="BENCH_cg
# BENCH_precond") to re-baseline only the files whose schema changed while
# the others stay gated.
#
# The comparator's own unit checks (scripts/bench_compare.py --self-test)
# run before anything is benched: a broken gate must fail the smoke run,
# not wave a regression through.
#
# Usage: scripts/bench_smoke.sh [mvm_output.json] [cg_output.json] [precond_output.json] [conf_output.json] [service_output.json] [trace_output.json]
set -euo pipefail

repo_root="$(cd "$(dirname "$0")/.." && pwd)"
out_mvm="${1:-$repo_root/BENCH_mvm.json}"
out_cg="${2:-$repo_root/BENCH_cg.json}"
out_precond="${3:-$repo_root/BENCH_precond.json}"
out_conf="${4:-$repo_root/BENCH_conf.json}"
out_service="${5:-$repo_root/BENCH_service.json}"
out_trace="${6:-$repo_root/BENCH_trace.json}"

# Prove the gate itself works before trusting it with real rows.
python3 "$repo_root/scripts/bench_compare.py" --self-test

# Write the fresh run to .new files first, gate it against the current
# baselines, and only rotate once everything passed — neither a failed
# bench nor a regressed run may replace the baseline (otherwise a rerun
# would compare the regression against itself and print OK).
cd "$repo_root/rust"
cargo bench --bench bench_perf_mvm -- --smoke \
    --json "$out_mvm.new" --json-cg "$out_cg.new" --json-precond "$out_precond.new" \
    --json-conf "$out_conf.new" --json-service "$out_service.new" \
    --json-trace "$out_trace.new"

echo "BENCH_mvm rows:"
cat "$out_mvm.new"
echo "BENCH_cg rows:"
cat "$out_cg.new"
echo "BENCH_precond rows:"
cat "$out_precond.new"
echo "BENCH_conf rows:"
cat "$out_conf.new"
echo "BENCH_service rows:"
cat "$out_service.new"
echo "BENCH_trace rows:"
cat "$out_trace.new"

# True when the gate is suppressed for this output file: "1" skips all,
# otherwise BENCH_SKIP_COMPARE is a list of file stems to skip.
skip_compare() {
    local name
    name="$(basename "$1")"
    case "${BENCH_SKIP_COMPARE:-0}" in
        1) return 0 ;;
        0 | "") return 1 ;;
        *)
            local stem
            for stem in $BENCH_SKIP_COMPARE; do
                if [[ "$name" == "$stem"* ]]; then
                    return 0
                fi
            done
            return 1
            ;;
    esac
}

# One-time conf re-baseline: an old-format BENCH_conf (no "mvms" key)
# predates the two-axis conf sweep — its adaptive rows can't match the
# reshaped tolerance grid, so comparing would only hit the matched==0
# error by hand. Skip the gate for that file only and rotate the new
# format in; every later run has "mvms" in the baseline and stays gated.
# (Deliberate BENCH_SKIP_COMPARE=1 already skips everything; don't turn
# it into a stem list.)
if [[ "${BENCH_SKIP_COMPARE:-0}" != "1" ]] \
    && [[ -f "$out_conf" ]] && ! grep -q '"mvms"' "$out_conf"; then
    echo "bench_smoke: BENCH_conf baseline predates the two-axis conf sweep;" \
         "re-baselining it this run"
    BENCH_SKIP_COMPARE="${BENCH_SKIP_COMPARE:-} BENCH_conf"
fi

fail=0
for out in "$out_mvm" "$out_cg" "$out_precond" "$out_conf" "$out_service" "$out_trace"; do
    if [[ -f "$out" ]] && ! skip_compare "$out"; then
        python3 "$repo_root/scripts/bench_compare.py" "$out" "$out.new" || fail=1
    fi
done
if [[ "$fail" != "0" ]]; then
    echo "bench_smoke: regression gate failed; baselines kept," \
         "fresh run left in BENCH_*.json.new for inspection" >&2
    exit 2
fi

for out in "$out_mvm" "$out_cg" "$out_precond" "$out_conf" "$out_service" "$out_trace"; do
    if [[ -f "$out" ]]; then
        mv "$out" "${out%.json}.prev.json"
    fi
    mv "$out.new" "$out"
done
