#!/usr/bin/env bash
# Perf smoke: run the blocked-MVM sweep (dense / Toeplitz / SKI at
# n in {1k, 4k}, b in {1, 8, 32}) and the block-CG solve sweep (same
# operator structures, 8 RHS, block in {1, 8}), emitting BENCH_mvm.json
# and BENCH_cg.json at the repo root so successive PRs have a throughput
# trajectory — MVMs *and* solves — to compare against.
#
# Usage: scripts/bench_smoke.sh [mvm_output.json] [cg_output.json]
set -euo pipefail

repo_root="$(cd "$(dirname "$0")/.." && pwd)"
out_mvm="${1:-$repo_root/BENCH_mvm.json}"
out_cg="${2:-$repo_root/BENCH_cg.json}"

cd "$repo_root/rust"
cargo bench --bench bench_perf_mvm -- --smoke --json "$out_mvm" --json-cg "$out_cg"

echo "BENCH_mvm rows:"
cat "$out_mvm"
echo "BENCH_cg rows:"
cat "$out_cg"
