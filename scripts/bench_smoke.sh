#!/usr/bin/env bash
# Perf smoke: run the blocked-MVM sweep (dense / Toeplitz / SKI at
# n in {1k, 4k}, b in {1, 8, 32}) and emit BENCH_mvm.json at the repo root
# so successive PRs have a throughput trajectory to compare against.
#
# Usage: scripts/bench_smoke.sh [output.json]
set -euo pipefail

repo_root="$(cd "$(dirname "$0")/.." && pwd)"
out="${1:-$repo_root/BENCH_mvm.json}"

cd "$repo_root/rust"
cargo bench --bench bench_perf_mvm -- --smoke --json "$out"

echo "BENCH_mvm rows:"
cat "$out"
