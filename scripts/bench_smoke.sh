#!/usr/bin/env bash
# Perf smoke: run the blocked-MVM sweep (dense / Toeplitz / SKI at
# n in {1k, 4k}, b in {1, 8, 32}), the block-CG solve sweep (same
# operator structures, 8 RHS, block in {1, 8}), and the pivoted-Cholesky
# preconditioning sweep (rank x sigma on an ill-conditioned dense RBF),
# emitting BENCH_mvm.json, BENCH_cg.json, and BENCH_precond.json at the
# repo root so successive PRs have a throughput trajectory — MVMs, solves,
# and preconditioned iteration counts — to compare against.
#
# When a previous BENCH_*.json exists it is rotated to BENCH_*.prev.json
# and diffed against the fresh run with scripts/bench_compare.py, which
# fails loudly (exit 2) on >20% regressions in timing or iteration/MVM
# counts. Set BENCH_SKIP_COMPARE=1 to suppress the gate (e.g. when moving
# between machines, where wall-clock baselines are meaningless).
#
# Usage: scripts/bench_smoke.sh [mvm_output.json] [cg_output.json] [precond_output.json]
set -euo pipefail

repo_root="$(cd "$(dirname "$0")/.." && pwd)"
out_mvm="${1:-$repo_root/BENCH_mvm.json}"
out_cg="${2:-$repo_root/BENCH_cg.json}"
out_precond="${3:-$repo_root/BENCH_precond.json}"

# Write the fresh run to .new files first, gate it against the current
# baselines, and only rotate once everything passed — neither a failed
# bench nor a regressed run may replace the baseline (otherwise a rerun
# would compare the regression against itself and print OK).
cd "$repo_root/rust"
cargo bench --bench bench_perf_mvm -- --smoke \
    --json "$out_mvm.new" --json-cg "$out_cg.new" --json-precond "$out_precond.new"

echo "BENCH_mvm rows:"
cat "$out_mvm.new"
echo "BENCH_cg rows:"
cat "$out_cg.new"
echo "BENCH_precond rows:"
cat "$out_precond.new"

if [[ "${BENCH_SKIP_COMPARE:-0}" != "1" ]]; then
    fail=0
    for out in "$out_mvm" "$out_cg" "$out_precond"; do
        if [[ -f "$out" ]]; then
            python3 "$repo_root/scripts/bench_compare.py" "$out" "$out.new" || fail=1
        fi
    done
    if [[ "$fail" != "0" ]]; then
        echo "bench_smoke: regression gate failed; baselines kept," \
             "fresh run left in BENCH_*.json.new for inspection" >&2
        exit 2
    fi
fi

for out in "$out_mvm" "$out_cg" "$out_precond"; do
    if [[ -f "$out" ]]; then
        mv "$out" "${out%.json}.prev.json"
    fi
    mv "$out.new" "$out"
done
